//! Simulated Gaudi engine replica: the analytical performance model
//! ([`crate::gaudisim`]) wrapped in the engine's continuous-batching
//! discipline, advancing a **virtual clock** instead of wall time.
//!
//! Each replica owns its own simulated device and a [`BlockAllocator`]
//! sized from that device's HBM minus the FP8 model weights — so fleet
//! admission control exercises the same OOM frontier Table 6 maps. Step
//! timing comes from [`prefill_tflops`] / [`decode_group_time_s_paged`]
//! (per-slot paged KV reads, matching the engine's block-table-native
//! decode; `dense_decode` switches to the pre-paged dense-copy reference
//! pricing), which means routing experiments inherit the paper's
//! performance shape (long prompts are expensive, decode is memory-bound)
//! without needing the PJRT artifacts.
//!
//! With `prefix_cache` enabled the replica shares prompt KV through a
//! [`PrefixCache`] drawing on the *same* block pool: admission charges
//! only the uncached tail (plus generation budget), freshly prefilled
//! prompts transfer their block-aligned prefix into the cache, and
//! admission pressure evicts refcount-0 LRU subtrees back into the pool —
//! so the byte contract stays exact end to end. Warm prompts pay the
//! chunked-tail prefill time ([`chunked_prefill_time_s`]) instead of the
//! full bucket.
//!
//! Under overload (ISSUE 9) the replica preempts instead of queueing
//! forever behind a full pool: when admission or decode growth would
//! exhaust the blocks, the least-recently-scheduled victim yields its
//! residency — its blocks move to a byte-budgeted host tier
//! ([`HostTier`], swap) or are dropped for a chunked re-prefill
//! (recompute); `auto` prices the PCIe round trip
//! ([`Device::host_transfer_time_s`]) against the re-prefill and takes
//! the cheaper path. Preempted sequences resume FIFO, strictly ahead of
//! new arrivals, and resumption never preempts anyone else. With the
//! tier off (`host_kv_bytes == 0`, the default) admission charges the
//! full lifetime footprint up front and behavior is bit-identical to the
//! pre-tier replica.

use std::collections::VecDeque;

use anyhow::Result;

use crate::coordinator::{
    chunk_spans, select_preemption_victim, warm_admittable_without_bucket, warm_start_pays,
    BlockAllocator, HostTier, PreemptCandidate, PreemptPolicy, PrefixCache, PrefixCacheConfig,
    Request, RequestId, RequestOutput, SchedulePolicy, Scheduler, ServeMetrics,
};
use crate::gaudisim::{
    chunked_prefill_report, chunked_prefill_time_s, decode_group_report_paged,
    decode_step_tflops_dense, kv_read_bytes_dense, kv_read_bytes_paged, prefill_tflops,
    speculative_expected_tokens_per_round, speculative_round_time_s, Device, E2eConfig,
    MemoryModel, ScalingKind,
};
use crate::model::config::{ModelConfig, ModelFamily};
use crate::obs::{Clock, StepStats, TraceEventKind, TraceRecorder};
use crate::quant::KvDtype;

use super::{Admission, ReplicaHandle};

#[derive(Clone, Debug)]
pub struct SimReplicaConfig {
    pub e2e: E2eConfig,
    /// Concurrent decode slots.
    pub slots: usize,
    /// Local admission-queue bound (beyond it, the fleet queue holds).
    pub queue_capacity: usize,
    pub block_tokens: usize,
    /// KV-cache storage dtype: sets the bytes/token rate (via the shared
    /// `KvLayout`) that sizes this replica's block pool. FP8 — the
    /// paper's serving configuration — by default.
    pub kv_dtype: KvDtype,
    /// Override the HBM-derived KV byte budget (equal-budget dtype
    /// comparisons pin this; None = device HBM minus FP8 weights).
    pub kv_bytes_budget_override: Option<f64>,
    /// Override the KV block budget directly (tests use small values to
    /// exercise the OOM admission path).
    pub kv_blocks_override: Option<usize>,
    /// Share prompt KV across requests through a radix prefix cache drawing
    /// on the same block pool (off by default: cold-path behavior is then
    /// bit-identical to the pre-cache replica).
    pub prefix_cache: bool,
    /// Chunked-prefill chunk size in tokens for cache-hit tails
    /// (0 = single-chunk tail).
    pub prefill_chunk: usize,
    /// Price decode through the **dense-copy reference** model instead of
    /// the paged reads: context-packed groups, every bucket row padded to
    /// the group-max context (the pre-paged engine's cost shape). Off by
    /// default — the block-table-native path charges each slot's actual
    /// live blocks. For paged-vs-dense A/B comparisons only.
    pub dense_decode: bool,
    /// Host-DRAM byte budget for the KV swap tier backing preemption
    /// (ISSUE 9). `0.0` disables the tier entirely: admission then
    /// charges the full lifetime footprint up front and the replica
    /// never preempts — bit-identical to the pre-tier replica.
    pub host_kv_bytes: f64,
    /// How preempted sequences resume: always swap through the host
    /// tier, always re-prefill chunked, or price both and take the
    /// cheaper (`Auto`). Irrelevant while `host_kv_bytes == 0`.
    pub preempt_policy: PreemptPolicy,
    /// Draft-verify speculative decoding (ISSUE 10): the tiny draft
    /// proposes this many tokens per round, the target verifies them in
    /// one chunked multi-token step (0 = off). Priced only for
    /// single-stream decode — exactly one resident sequence; a batch
    /// already amortizes the per-step overhead speculation exists to
    /// hide.
    pub spec_gamma: usize,
    /// Modeled acceptance rate α ∈ [0, 1]: the expected fraction of
    /// draft tokens the target's greedy accept-prefix verify keeps.
    pub spec_acceptance: f64,
    /// Width-k beam groups (1 = off): admission forks `k-1` co-resident
    /// branches that decode in lockstep and retire as one request.
    pub beam_width: usize,
    pub prefill_seqs: Vec<usize>,
    pub decode_batches: Vec<usize>,
}

impl SimReplicaConfig {
    /// Fast synthetic model on a simulated Gaudi 2 — the test/bench default.
    pub fn synthetic_tiny() -> Self {
        Self {
            e2e: E2eConfig {
                model: ModelConfig::synthetic_tiny(ModelFamily::Llama3),
                device: Device::gaudi2(),
                scaling: ScalingKind::PerTensorHwPow2,
                lm_head_bf16: true,
            },
            slots: 4,
            queue_capacity: 256,
            block_tokens: 16,
            kv_dtype: KvDtype::FP8_DEFAULT,
            kv_bytes_budget_override: None,
            kv_blocks_override: None,
            prefix_cache: false,
            prefill_chunk: 0,
            dense_decode: false,
            host_kv_bytes: 0.0,
            preempt_policy: PreemptPolicy::Auto,
            spec_gamma: 0,
            spec_acceptance: 0.8,
            beam_width: 1,
            prefill_seqs: vec![16, 32, 64, 128, 256, 512, 1024],
            decode_batches: vec![1, 2, 4, 8],
        }
    }

    /// The paper's Llama v3.1 70B on Gaudi 2 (Tables 5/6 geometry).
    pub fn gaudi2_llama31_70b() -> Self {
        Self {
            e2e: E2eConfig::llama31_70b_paper(),
            slots: 16,
            queue_capacity: 256,
            block_tokens: 16,
            kv_dtype: KvDtype::FP8_DEFAULT,
            kv_bytes_budget_override: None,
            kv_blocks_override: None,
            prefix_cache: false,
            prefill_chunk: 0,
            dense_decode: false,
            host_kv_bytes: 0.0,
            preempt_policy: PreemptPolicy::Auto,
            spec_gamma: 0,
            spec_acceptance: 0.8,
            beam_width: 1,
            prefill_seqs: vec![1024, 2048, 4096, 8192, 16384],
            decode_batches: vec![1, 8, 16, 32, 64, 128],
        }
    }
}

struct SimActive {
    id: RequestId,
    prompt: Vec<i32>,
    /// Cached-prefix tokens pinned in the prefix cache for this request's
    /// lifetime.
    cache_tokens: usize,
    max_new: usize,
    generated: usize,
    /// Queueing + prefill latency, computed at admission.
    ttft_s: f64,
    first_token_s: f64,
    /// Privately held blocks (tail + generation; cached-prefix blocks are
    /// pool-charged to the cache instead).
    blocks: usize,
    /// Current context length (prompt + generated), drives KV-read cost.
    context: usize,
    /// Virtual-clock stamp of the last decode step (or admission) that
    /// scheduled this sequence — preemption victims are picked
    /// least-recently-scheduled first.
    last_scheduled_s: f64,
    /// Blocks of history this row shares with its beam siblings (the
    /// prompt KV at fork time, owned by the root's allocation). Growth
    /// charges only blocks past this shared span. 0 for plain rows.
    shared_blocks: usize,
    /// Width of this row's beam group (1 = not a beam branch). All k
    /// rows of a group share one request id and retire as one output.
    beam_width: usize,
}

/// How a specific preempted sequence gets back on the device — fixed at
/// preempt time so the accounting (host budget, transfer spans) matches
/// the decision the policy actually took.
enum ResumeMode {
    /// `blocks` are parked in the host tier; resume re-allocates them and
    /// pays the PCIe transfer back.
    SwapIn { blocks: usize },
    /// Blocks were dropped; resume re-prefills the full context chunked,
    /// warming back through whatever prefix is still cached.
    Recompute,
}

struct PreemptedSeq {
    a: SimActive,
    resume: ResumeMode,
}

pub struct SimReplica {
    label: String,
    cfg: SimReplicaConfig,
    sched: Scheduler,
    alloc: BlockAllocator,
    prefix: Option<PrefixCache>,
    queue: VecDeque<(Request, f64)>,
    active: Vec<SimActive>,
    /// Sequences preempted off the device, FIFO; resumed strictly ahead
    /// of new arrivals.
    preempted: VecDeque<PreemptedSeq>,
    /// Host-DRAM swap tier (`None` = preemption disabled). The sim
    /// models transfers on the virtual clock without materializing
    /// bytes, so payloads are `()`.
    host: Option<HostTier<()>>,
    now_s: f64,
    metrics: ServeMetrics,
    finished: Vec<RequestOutput>,
    /// Lifecycle trace recorder (None = tracing off; the default, so the
    /// hot path pays nothing).
    trace: Option<TraceRecorder>,
    /// Draft-model pricing config for speculative rounds (`None` while
    /// `spec_gamma == 0`): the tiny synthetic geometry on the *target's*
    /// device, so draft and verify share one roofline.
    draft_e2e: Option<E2eConfig>,
    /// Fractional accepted-token credit carried between speculative
    /// rounds: each round banks `speculative_expected_tokens_per_round`
    /// and emits the integer part, so long-run throughput matches the
    /// analytic expectation exactly with an RNG-free virtual clock.
    spec_credit: f64,
}

impl SimReplica {
    pub fn new(label: &str, mut cfg: SimReplicaConfig) -> Result<Self> {
        // A 0-slot replica could accept work it can never start, wedging
        // the fleet event loop on a busy-but-stuck replica.
        cfg.slots = cfg.slots.max(1);
        let alloc = match cfg.kv_blocks_override {
            Some(blocks) => BlockAllocator::new(blocks, cfg.block_tokens),
            None => {
                // Same accounting contract as the capacity model and the
                // engine's host store: bytes/token from the shared KvLayout.
                let mm = MemoryModel::new(cfg.e2e.device, cfg.e2e.model.clone())
                    .with_kv_dtype(cfg.kv_dtype);
                let budget = cfg
                    .kv_bytes_budget_override
                    .unwrap_or_else(|| mm.capacity_bytes() - mm.weight_bytes_fp8());
                BlockAllocator::from_layout(budget, &mm.kv_layout(), cfg.block_tokens)?
            }
        };
        let prefix = if cfg.prefix_cache {
            // The cache draws on the same pool; its only budget is the
            // pool itself (admission-pressure eviction keeps it honest).
            Some(PrefixCache::new(PrefixCacheConfig {
                block_tokens: cfg.block_tokens,
                max_blocks: alloc.total_blocks,
                layout: cfg.e2e.model.kv_layout(cfg.kv_dtype),
            }))
        } else {
            None
        };
        let sched = Scheduler::new(
            SchedulePolicy::PrefillFirst,
            cfg.prefill_seqs.clone(),
            cfg.decode_batches.clone(),
        );
        let host = if cfg.host_kv_bytes > 0.0 {
            Some(HostTier::new(
                cfg.host_kv_bytes as usize,
                &cfg.e2e.model.kv_layout(cfg.kv_dtype),
                cfg.block_tokens,
            ))
        } else {
            None
        };
        let draft_e2e = (cfg.spec_gamma > 0).then(|| E2eConfig {
            model: ModelConfig::synthetic_tiny(ModelFamily::Llama3),
            device: cfg.e2e.device,
            scaling: cfg.e2e.scaling,
            lm_head_bf16: cfg.e2e.lm_head_bf16,
        });
        Ok(Self {
            label: label.to_string(),
            cfg,
            sched,
            alloc,
            prefix,
            queue: VecDeque::new(),
            active: Vec::new(),
            preempted: VecDeque::new(),
            host,
            now_s: 0.0,
            metrics: ServeMetrics::new(),
            finished: Vec::new(),
            trace: None,
            draft_e2e,
            spec_credit: 0.0,
        })
    }

    pub fn allocator(&self) -> &BlockAllocator {
        &self.alloc
    }

    /// The replica's prefix cache, when enabled.
    pub fn prefix_cache(&self) -> Option<&PrefixCache> {
        self.prefix.as_ref()
    }

    /// The host swap tier, when preemption is enabled.
    pub fn host_tier(&self) -> Option<&HostTier<()>> {
        self.host.as_ref()
    }

    /// The cached prompt paths — the hot subtrees a host-tier-persisted
    /// cache carries across a replica restart (ISSUE 9). Pair with
    /// [`Self::restore_prefixes`] on the replacement replica.
    pub fn snapshot_prefixes(&self) -> Vec<Vec<i32>> {
        self.prefix.as_ref().map_or_else(Vec::new, |p| p.hot_paths())
    }

    /// Seed this (fresh) replica's prefix cache from a snapshot, charging
    /// the pool at the usual block rate. Paths that no longer fit are
    /// skipped. Returns the tokens restored.
    pub fn restore_prefixes(&mut self, paths: &[Vec<i32>]) -> usize {
        let bt = self.cfg.block_tokens;
        let mut tokens = 0usize;
        for path in paths {
            let Some(pc) = self.prefix.as_mut() else {
                break;
            };
            let aligned = path.len() - path.len() % bt;
            let new = aligned.saturating_sub(pc.lookup(path));
            if new == 0 || !self.alloc.can_allocate_blocks(new / bt) {
                continue;
            }
            let rep = pc.insert(path);
            if rep.evicted_blocks > 0 {
                self.metrics.prefix_evicted_blocks += rep.evicted_blocks as u64;
                self.alloc
                    .release(rep.evicted_blocks)
                    // lint:allow(no-unwrap-in-lib): the allocator accounted these blocks to the cache; release cannot underflow
                    .expect("evicted cache blocks return to the pool");
            }
            if rep.new_tokens > 0 {
                self.alloc
                    .allocate_blocks(rep.new_tokens / bt)
                    // lint:allow(no-unwrap-in-lib): headroom for the whole path was checked before the insert
                    .expect("restore charged within checked headroom");
                tokens += rep.new_tokens;
            }
        }
        tokens
    }

    /// Complete a request that can never run here with an empty output
    /// (mirrors the engine's unservable path) rather than wedging the
    /// queue.
    fn finish_unservable(&mut self, req: Request) {
        if let Some(tr) = self.trace.as_mut() {
            tr.record_at(
                self.now_s,
                Some(req.id),
                TraceEventKind::Reject {
                    reason: "unservable".to_string(),
                },
            );
        }
        self.finished.push(RequestOutput {
            id: req.id,
            prompt_len: req.prompt.len(),
            tokens: Vec::new(),
            ttft_s: 0.0,
            tpot_s: 0.0,
            total_s: 0.0,
        });
        // Count it completed so fleet reports agree with outputs.
        self.metrics.requests_completed += 1;
    }

    /// Admit at most one queued request (the engine's one-prefill-per-step
    /// interleave). Returns whether anything happened.
    fn admit_one_prefill(&mut self) -> bool {
        if self.resume_one_preempted() {
            return true;
        }
        if !self.preempted.is_empty() {
            // Preempted sequences hold strict re-admission priority:
            // admitting new arrivals past them would starve them behind
            // an endless arrival stream.
            return false;
        }
        if self.active.len() >= self.cfg.slots {
            return false;
        }
        let Some((req, arrival_s)) = self.queue.pop_front() else {
            return false;
        };
        let prompt_len = req.prompt.len();
        let bt = self.cfg.block_tokens;
        let total_need = self.alloc.blocks_for(prompt_len + req.max_new_tokens);
        if total_need > self.alloc.total_blocks {
            // Even an idle replica could not hold this request (shared
            // blocks included: every token must still be resident).
            self.finish_unservable(req);
            return true;
        }
        // Pin the cached prefix first — eviction must not free it from
        // under this request — then decide warm vs cold with the same
        // rule the scheduler applies for the engine.
        let mut cached = match self.prefix.as_mut() {
            Some(p) => p.acquire(&req.prompt),
            None => 0,
        };
        let bucket_opt = self.sched.prefill_bucket(prompt_len);
        if !warm_start_pays(cached, prompt_len, bucket_opt.is_some()) {
            if cached > 0 {
                if let Some(p) = self.prefix.as_mut() {
                    p.release(&req.prompt, cached);
                }
                cached = 0;
            }
            // Cold, and no compiled bucket fits: can never prefill here.
            if bucket_opt.is_none() {
                self.finish_unservable(req);
                return true;
            }
        }
        // Width-k beam groups (ISSUE 10): admission forks `k-1` branches
        // off the freshly prefilled prompt KV. Branches share the prompt
        // history (CoW in the engine), so each charges only its divergent
        // growth: the blocks past the fork point plus one copied-on-write
        // hot block. Width degrades rather than wedging — the group must
        // fit the slots and the pool as one co-resident unit.
        let mut width = req
            .beam_width
            .unwrap_or(self.cfg.beam_width)
            .max(1)
            .min(self.cfg.decode_batches.last().copied().unwrap_or(1).max(1));
        let branch_total = self.alloc.blocks_for(prompt_len + req.max_new_tokens.max(1))
            - self.alloc.blocks_for(prompt_len + 1)
            + 1;
        while width > 1 {
            let slots_ok = self.active.len() + width <= self.cfg.slots;
            let pool_ok = total_need + (width - 1) * branch_total <= self.alloc.total_blocks;
            if slots_ok && pool_ok {
                break;
            }
            width -= 1;
        }
        // With the host tier on, admission charges only the resident
        // prefill footprint (prompt + first token); generation then grows
        // block-by-block, preempting under pressure. Tier off keeps the
        // legacy whole-lifetime charge (branches included).
        let (resident_need, branch_blocks) = if self.host.is_some() {
            (self.alloc.blocks_for(prompt_len + 1), 0)
        } else {
            (total_need, branch_total)
        };
        let need_blocks = resident_need - cached / bt + (width - 1) * branch_blocks;
        // Reclaim refcount-0 cached blocks before anything drastic.
        self.evict_cache_for(need_blocks);
        if !self.alloc.can_allocate_blocks(need_blocks) && self.host.is_some() {
            // Overload: take residency from the least-recently-scheduled
            // victim instead of queueing behind a full pool.
            self.preempt_until(need_blocks, None);
        }
        if !self.alloc.can_allocate_blocks(need_blocks) {
            // Blocks will free as active requests retire: wait.
            if cached > 0 {
                if let Some(p) = self.prefix.as_mut() {
                    p.release(&req.prompt, cached);
                }
            }
            self.queue.push_front((req, arrival_s));
            return false;
        }
        self.alloc
            .allocate_blocks(need_blocks)
            // lint:allow(no-unwrap-in-lib): can_admit() verified the block budget in the branch above
            .expect("availability just checked");

        if self.active.is_empty() {
            // Idle replica: it was genuinely waiting for this arrival. With
            // work in flight the clock must NOT jump to a future-stamped
            // arrival (failover re-routes), or unrelated active requests
            // would absorb the jump into their latencies.
            self.now_s = self.now_s.max(arrival_s);
        }
        // Cold admissions keep the legacy bucketed whole-prompt prefill
        // cost; warm ones pay only the chunked uncached tail (or a single
        // bootstrap decode step on a full hit).
        let rep = if cached == 0 {
            // lint:allow(no-unwrap-in-lib): cold path only taken when a prefill bucket was found during admission
            let bucket = bucket_opt.expect("cold admission always has a bucket");
            prefill_tflops(&self.cfg.e2e, bucket)
        } else {
            chunked_prefill_report(&self.cfg.e2e, prompt_len, cached, self.cfg.prefill_chunk)
        };
        let t = rep.time_s;
        let admit_s = self.now_s;
        self.now_s += t;
        self.metrics.prefill_steps += 1;
        self.metrics.prefill_time.record(t);
        // Step-level utilization sample (gaudisim-modeled FLOPs over
        // modeled time, vs the device FP8 peak).
        let step = StepStats {
            time_s: t,
            model_flops: rep.model_flops,
            kv_bytes_read: 0,
            pool_occupancy: self.alloc.utilization(),
        };
        let step_mfu = step.apply(&mut self.metrics, self.cfg.e2e.device.peak_fp8_tflops);
        if let Some(tr) = self.trace.as_mut() {
            tr.record_at(
                admit_s,
                Some(req.id),
                TraceEventKind::Admit {
                    queued_s: (admit_s - arrival_s).max(0.0),
                },
            );
            if cached > 0 {
                tr.record_at(
                    admit_s,
                    Some(req.id),
                    TraceEventKind::PrefixHit { tokens: cached },
                );
            }
            tr.record_span(
                Some(req.id),
                admit_s,
                t,
                TraceEventKind::PrefillChunk {
                    tokens: prompt_len - cached,
                    mfu: step_mfu,
                },
            );
        }
        if self.prefix.is_some() {
            if cached > 0 {
                self.metrics.prefix_hits += 1;
                self.metrics.prefix_hit_tokens += cached as u64;
                self.metrics.prefill_chunks +=
                    chunk_spans(prompt_len, cached, self.cfg.prefill_chunk).len() as u64;
            } else {
                self.metrics.prefix_misses += 1;
            }
        }
        // A future-stamped request cannot have waited a negative time.
        let ttft = (self.now_s - arrival_s).max(t);
        self.metrics.ttft.record(ttft);
        self.metrics.prompt_tokens += prompt_len as u64;
        self.metrics.generated_tokens += 1; // first token sampled at prefill
        // Publish the freshly prefilled prompt into the shared cache: the
        // newly cached blocks transfer from this request's private
        // allocation to the cache (no pool delta), and the request re-pins
        // the full cached span for its lifetime.
        let mut cache_tokens = cached;
        let mut private_blocks = need_blocks;
        let mut insert_evicted = 0usize;
        if let Some(p) = self.prefix.as_mut() {
            let rep = p.insert(&req.prompt);
            insert_evicted = rep.evicted_blocks;
            if rep.new_tokens > 0 {
                p.release(&req.prompt, cached);
                cache_tokens = p.acquire(&req.prompt);
                private_blocks -= (cache_tokens - cached) / bt;
            }
        }
        if insert_evicted > 0 {
            // Defensive: the shared-pool invariant means inserts never need
            // room, but if one ever evicts, the blocks go back to the pool.
            self.metrics.prefix_evicted_blocks += insert_evicted as u64;
            self.alloc
                .release(insert_evicted)
                // lint:allow(no-unwrap-in-lib): the allocator accounted these blocks to the cache; release cannot underflow
                .expect("evicted cache blocks return to the pool");
            if let Some(tr) = self.trace.as_mut() {
                tr.record_at(
                    self.now_s,
                    None,
                    TraceEventKind::Evict {
                        blocks: insert_evicted as u64,
                    },
                );
            }
        }
        let max_new = req.max_new_tokens.max(1);
        self.active.push(SimActive {
            id: req.id,
            prompt: req.prompt.clone(),
            cache_tokens,
            max_new,
            generated: 1,
            ttft_s: ttft,
            first_token_s: self.now_s,
            blocks: private_blocks - (width - 1) * branch_blocks,
            context: prompt_len + 1,
            last_scheduled_s: self.now_s,
            shared_blocks: 0,
            beam_width: width,
        });
        if width > 1 {
            // Forking is KV-table metadata in the engine — zero model
            // time; each branch's first token was sampled from the same
            // prefill logits row.
            self.metrics.beam_forks += (width - 1) as u64;
            self.metrics.generated_tokens += (width - 1) as u64;
            let shared = self.alloc.blocks_for(prompt_len + 1);
            for _ in 1..width {
                self.active.push(SimActive {
                    id: req.id,
                    prompt: req.prompt.clone(),
                    cache_tokens: 0,
                    max_new,
                    generated: 1,
                    ttft_s: ttft,
                    first_token_s: self.now_s,
                    blocks: branch_blocks,
                    context: prompt_len + 1,
                    last_scheduled_s: self.now_s,
                    shared_blocks: shared,
                    beam_width: width,
                });
            }
        }
        true
    }

    /// Reclaim refcount-0 cached blocks until `need` blocks are
    /// allocatable (or nothing evictable remains).
    fn evict_cache_for(&mut self, need: usize) {
        if self.alloc.can_allocate_blocks(need) {
            return;
        }
        if let Some(p) = self.prefix.as_mut() {
            let shortfall = need - self.alloc.free_blocks();
            let freed = p.evict_blocks(shortfall);
            if freed > 0 {
                self.metrics.prefix_evicted_blocks += freed as u64;
                self.alloc
                    .release(freed)
                    // lint:allow(no-unwrap-in-lib): the allocator accounted these blocks to the cache; release cannot underflow
                    .expect("evicted cache blocks return to the pool");
                if let Some(tr) = self.trace.as_mut() {
                    tr.record_at(
                        self.now_s,
                        None,
                        TraceEventKind::Evict {
                            blocks: freed as u64,
                        },
                    );
                }
            }
        }
    }

    /// Preempt victims — least-recently-scheduled first, fewest generated
    /// tokens as the tiebreak — until `need` blocks are allocatable or no
    /// victim remains. `protect` shields the sequence whose growth is
    /// being served from eviction by its own demand; victims' cache pins
    /// are released as they leave, so the next eviction pass can reclaim
    /// the blocks they were holding.
    fn preempt_until(&mut self, need: usize, protect: Option<RequestId>) {
        loop {
            self.evict_cache_for(need);
            if self.alloc.can_allocate_blocks(need) {
                return;
            }
            let cands: Vec<PreemptCandidate> = self
                .active
                .iter()
                .enumerate()
                .filter(|(_, a)| Some(a.id) != protect)
                // Beam groups stay co-resident: evicting one branch of a
                // group that must decode in lockstep stalls the whole
                // group, so branches are not preemption victims.
                .filter(|(_, a)| a.beam_width == 1)
                .filter(|(_, a)| a.blocks > 0 || a.cache_tokens > 0)
                .map(|(idx, a)| PreemptCandidate {
                    idx,
                    idle_s: self.now_s - a.last_scheduled_s,
                    generated: a.generated,
                })
                .collect();
            let Some(victim) = select_preemption_victim(&cands) else {
                return;
            };
            self.preempt_active(victim);
        }
    }

    /// Evict one active sequence from the device. Its cache pins are
    /// released (a recompute resume warms back through whatever is still
    /// cached), its private blocks either move to the host tier (swap) or
    /// are dropped (recompute), and it joins the FIFO resume queue.
    fn preempt_active(&mut self, idx: usize) {
        let mut a = self.active.swap_remove(idx);
        if a.cache_tokens > 0 {
            if let Some(p) = self.prefix.as_mut() {
                p.release(&a.prompt, a.cache_tokens);
            }
            a.cache_tokens = 0;
        }
        let blocks = a.blocks;
        let mut swap = false;
        let mut bytes = 0usize;
        if let Some(host) = self.host.as_mut() {
            bytes = blocks * host.block_bytes();
            let wants_swap = blocks > 0
                && match self.cfg.preempt_policy {
                    PreemptPolicy::Swap => true,
                    PreemptPolicy::Recompute => false,
                    // The round trip over the host link vs re-running the
                    // chunked prefill of the whole context.
                    PreemptPolicy::Auto => {
                        2.0 * self.cfg.e2e.device.host_transfer_time_s(bytes as f64)
                            < chunked_prefill_time_s(
                                &self.cfg.e2e,
                                a.context,
                                0,
                                self.cfg.prefill_chunk,
                            )
                    }
                };
            swap = wants_swap && host.store(a.id, blocks, ());
        }
        if blocks > 0 {
            self.alloc
                .release(blocks)
                // lint:allow(no-unwrap-in-lib): a preempted sequence frees exactly the blocks its admission and growth charged
                .expect("preempt releases exactly the blocks it held");
            a.blocks = 0;
        }
        self.metrics.preemptions += 1;
        if let Some(tr) = self.trace.as_mut() {
            tr.record_at(
                self.now_s,
                Some(a.id),
                TraceEventKind::Preempt {
                    blocks: blocks as u64,
                    swap,
                },
            );
        }
        if swap {
            self.metrics.swapped_out_blocks += blocks as u64;
            self.metrics.host_swap_bytes += bytes as u64;
            let t = self.cfg.e2e.device.host_transfer_time_s(bytes as f64);
            let start = self.now_s;
            self.now_s += t;
            if let Some(tr) = self.trace.as_mut() {
                tr.record_span(
                    Some(a.id),
                    start,
                    t,
                    TraceEventKind::SwapOut {
                        blocks: blocks as u64,
                        bytes: bytes as u64,
                    },
                );
            }
            self.preempted.push_back(PreemptedSeq {
                a,
                resume: ResumeMode::SwapIn { blocks },
            });
        } else {
            self.preempted.push_back(PreemptedSeq {
                a,
                resume: ResumeMode::Recompute,
            });
        }
    }

    /// Try to put the oldest preempted sequence back on the device.
    /// Resumption never preempts anyone else (two sequences trading
    /// residency would livelock); it waits for organic headroom.
    fn resume_one_preempted(&mut self) -> bool {
        if self.preempted.is_empty() || self.active.len() >= self.cfg.slots {
            return false;
        }
        let bt = self.cfg.block_tokens;
        let Some(PreemptedSeq { mut a, resume }) = self.preempted.pop_front() else {
            return false;
        };
        match resume {
            ResumeMode::SwapIn { blocks } => {
                self.evict_cache_for(blocks);
                if !self.alloc.can_allocate_blocks(blocks) {
                    self.preempted.push_front(PreemptedSeq {
                        a,
                        resume: ResumeMode::SwapIn { blocks },
                    });
                    return false;
                }
                self.alloc
                    .allocate_blocks(blocks)
                    // lint:allow(no-unwrap-in-lib): availability just checked
                    .expect("availability just checked");
                let mut bytes = 0usize;
                if let Some(host) = self.host.as_mut() {
                    if host.take(a.id).is_some() {
                        bytes = blocks * host.block_bytes();
                    }
                }
                let t = self.cfg.e2e.device.host_transfer_time_s(bytes as f64);
                let start = self.now_s;
                self.now_s += t;
                self.metrics.swapped_in_blocks += blocks as u64;
                self.metrics.host_swap_bytes += bytes as u64;
                if let Some(tr) = self.trace.as_mut() {
                    tr.record_span(
                        Some(a.id),
                        start,
                        t,
                        TraceEventKind::SwapIn {
                            blocks: blocks as u64,
                            bytes: bytes as u64,
                        },
                    );
                }
                a.blocks = blocks;
            }
            ResumeMode::Recompute => {
                let cached = match self.prefix.as_mut() {
                    Some(pc) => pc.acquire(&a.prompt),
                    None => 0,
                };
                let need = self.alloc.blocks_for(a.context).saturating_sub(cached / bt);
                self.evict_cache_for(need);
                if !self.alloc.can_allocate_blocks(need) {
                    if cached > 0 {
                        if let Some(pc) = self.prefix.as_mut() {
                            pc.release(&a.prompt, cached);
                        }
                    }
                    self.preempted.push_front(PreemptedSeq {
                        a,
                        resume: ResumeMode::Recompute,
                    });
                    return false;
                }
                self.alloc
                    .allocate_blocks(need)
                    // lint:allow(no-unwrap-in-lib): availability just checked
                    .expect("availability just checked");
                // Re-prefill the full context (prompt + generated so
                // far), chunked, warm over whatever is still cached.
                let rep = chunked_prefill_report(
                    &self.cfg.e2e,
                    a.context,
                    cached,
                    self.cfg.prefill_chunk,
                );
                let t = rep.time_s;
                let start = self.now_s;
                self.now_s += t;
                self.metrics.recompute_resumes += 1;
                self.metrics.prefill_steps += 1;
                self.metrics.prefill_time.record(t);
                let step = StepStats {
                    time_s: t,
                    model_flops: rep.model_flops,
                    kv_bytes_read: 0,
                    pool_occupancy: self.alloc.utilization(),
                };
                let step_mfu = step.apply(&mut self.metrics, self.cfg.e2e.device.peak_fp8_tflops);
                if let Some(tr) = self.trace.as_mut() {
                    tr.record_span(
                        Some(a.id),
                        start,
                        t,
                        TraceEventKind::PrefillChunk {
                            tokens: a.context - cached,
                            mfu: step_mfu,
                        },
                    );
                }
                a.cache_tokens = cached;
                a.blocks = need;
                // The re-prefill re-materialized the whole context into
                // this row's own allocation — nothing is shared anymore.
                a.shared_blocks = 0;
            }
        }
        a.last_scheduled_s = self.now_s;
        self.active.push(a);
        true
    }

    /// Tier-on decode pre-pass: every active sequence gets room for the
    /// token this round appends, growing block-by-block and preempting
    /// under pressure. A sequence that cannot take a block from anyone
    /// else yields its own residency (self-preempt) and resumes once
    /// blocks free up.
    fn ensure_decode_headroom(&mut self) {
        if self.host.is_none() {
            return;
        }
        let bt = self.cfg.block_tokens;
        let mut i = 0;
        while i < self.active.len() {
            let (id, need_extra) = {
                let a = &self.active[i];
                // A beam branch owns only the blocks past its shared fork
                // history (the root holds the prompt span).
                let private_need = (self.alloc.blocks_for(a.context + 1) - a.cache_tokens / bt)
                    .saturating_sub(a.shared_blocks);
                (a.id, private_need.saturating_sub(a.blocks))
            };
            if need_extra == 0 {
                i += 1;
                continue;
            }
            self.evict_cache_for(need_extra);
            if !self.alloc.can_allocate_blocks(need_extra) {
                self.preempt_until(need_extra, Some(id));
            }
            if self.alloc.can_allocate_blocks(need_extra) {
                self.alloc
                    .allocate_blocks(need_extra)
                    // lint:allow(no-unwrap-in-lib): availability just checked
                    .expect("availability just checked");
                if let Some(j) = self.growth_row(id) {
                    self.active[j].blocks += need_extra;
                }
            } else if let Some(j) = self.growth_row(id) {
                self.preempt_active(j);
            }
            // Preemption swap_removes victims: indices shifted, rescan.
            // Terminates — each pass either grows a sequence (its demand
            // drops to zero) or removes one from `active`.
            i = 0;
        }
    }

    /// Index of the row with this id whose block demand for the next
    /// token is still unmet. Beam branches share one request id, so a
    /// plain first-id-match could credit growth blocks to a sibling that
    /// needs nothing (and re-demand forever); falls back to the first id
    /// match when every sibling is satisfied.
    fn growth_row(&self, id: RequestId) -> Option<usize> {
        let bt = self.cfg.block_tokens;
        let mut first = None;
        for (j, a) in self.active.iter().enumerate() {
            if a.id != id {
                continue;
            }
            let unmet = (self.alloc.blocks_for(a.context + 1) - a.cache_tokens / bt)
                .saturating_sub(a.shared_blocks)
                > a.blocks;
            if unmet {
                return Some(j);
            }
            first.get_or_insert(j);
        }
        first
    }

    /// One draft-verify speculative round for the lone resident sequence
    /// (ISSUE 10): the draft decodes γ proposals, the target verifies all
    /// γ+1 positions in one chunked multi-token step
    /// ([`speculative_round_time_s`]), and the accepted-token yield flows
    /// through a deterministic fractional-credit accumulator seeded from
    /// the modeled acceptance rate — the virtual clock stays RNG-free
    /// (clock discipline) while long-run throughput matches
    /// [`speculative_expected_tokens_per_round`] exactly.
    ///
    /// Returns `false` (caller falls back to the plain decode round) when
    /// speculation is off, more than one sequence is resident — a batch
    /// already amortizes the per-step overhead speculation hides — or the
    /// pool cannot grow by this round's kept tokens.
    fn speculative_round(&mut self) -> bool {
        if self.cfg.spec_gamma == 0 || self.active.len() != 1 {
            return false;
        }
        if self.active[0].beam_width > 1 {
            // Engine parity: beam branches carry scores the accept-prefix
            // rule does not model — a lone surviving branch decodes plain.
            return false;
        }
        let gamma = self.cfg.spec_gamma;
        let bt = self.cfg.block_tokens;
        let (id, ctx, remaining) = {
            let a = &self.active[0];
            (a.id, a.context, a.max_new - a.generated)
        };
        if remaining == 0 {
            return false;
        }
        let t = {
            let Some(draft) = self.draft_e2e.as_ref() else {
                return false;
            };
            speculative_round_time_s(&self.cfg.e2e, draft, ctx, gamma)
        };
        let alpha = self.cfg.spec_acceptance.clamp(0.0, 1.0);
        let expected = speculative_expected_tokens_per_round(gamma, alpha);
        let n = ((self.spec_credit + expected).floor() as usize)
            .clamp(1, gamma + 1)
            .min(remaining);
        // Headroom for the n tokens this round keeps. The engine's
        // optimistic appends past the kept prefix are rolled back by
        // truncation within the round, so they never hold blocks across
        // rounds.
        let need_extra = {
            let a = &self.active[0];
            (self.alloc.blocks_for(ctx + n) - a.cache_tokens / bt)
                .saturating_sub(a.shared_blocks)
                .saturating_sub(a.blocks)
        };
        if need_extra > 0 {
            self.evict_cache_for(need_extra);
            if !self.alloc.can_allocate_blocks(need_extra) {
                // Let the plain round grow block-by-block and preempt.
                return false;
            }
            self.alloc
                .allocate_blocks(need_extra)
                // lint:allow(no-unwrap-in-lib): availability just checked
                .expect("availability just checked");
            self.active[0].blocks += need_extra;
        }
        self.spec_credit += expected - n as f64;
        let accepted = n - 1;
        let rejected = gamma - accepted;
        let start_s = self.now_s;
        self.now_s += t;
        self.metrics.spec_rounds += 1;
        self.metrics.spec_accepted_tokens += accepted as u64;
        self.metrics.spec_rejected_tokens += rejected as u64;
        if rejected > 0 {
            self.metrics.spec_rollbacks += 1;
        }
        self.metrics.decode_steps += 1;
        self.metrics.decode_batch_sum += 1;
        self.metrics.decode_time.record(t);
        self.metrics.generated_tokens += n as u64;
        for _ in 0..n {
            self.metrics.tpot.record(t / n as f64);
        }
        if let Some(tr) = self.trace.as_mut() {
            tr.record_at(start_s, Some(id), TraceEventKind::DraftPropose { gamma });
            tr.record_span(
                Some(id),
                start_s,
                t,
                TraceEventKind::VerifyAccept {
                    accepted,
                    emitted: n,
                },
            );
            if rejected > 0 {
                // The tail blocks the optimistic γ+1 appends would have
                // dirtied past the kept context — truncation's reclaim.
                let blocks = self
                    .alloc
                    .blocks_for(ctx + 1 + gamma)
                    .saturating_sub(self.alloc.blocks_for(ctx + n));
                tr.record_at(
                    self.now_s,
                    Some(id),
                    TraceEventKind::Rollback {
                        tokens: rejected,
                        blocks: blocks as u64,
                    },
                );
            }
        }
        let a = &mut self.active[0];
        a.generated += n;
        a.context += n;
        a.last_scheduled_s = self.now_s;
        true
    }

    /// One decode step for every active request, split into compiled batch
    /// groups like the real engine.
    ///
    /// Pricing follows the engine's block-table-native path: each group
    /// charges the sum of its members' live block bytes
    /// ([`decode_group_time_s_paged`]) — bucket padding rows read nothing
    /// and no row pays another's context. With `dense_decode` the replica
    /// instead reproduces the pre-paged cost shape: context-packed groups
    /// whose every bucket row is padded to the group-max context.
    fn decode_round(&mut self) -> bool {
        if self.active.is_empty() {
            return false;
        }
        self.ensure_decode_headroom();
        if self.active.is_empty() {
            // Everyone yielded residency; preemption was the progress.
            return true;
        }
        let groups: Vec<Vec<usize>> = if self.cfg.dense_decode {
            let slots_ctx: Vec<(usize, usize)> = (0..self.active.len())
                .map(|i| (i, self.active[i].context))
                .collect();
            self.sched.decode_groups_dense_ctx(&slots_ctx)
        } else {
            let idxs: Vec<usize> = (0..self.active.len()).collect();
            self.sched.decode_groups(&idxs)
        };
        for group in groups {
            // Step report (time + model FLOPs) and physical KV bytes read,
            // under whichever pricing model is active.
            let (rep, kv_bytes) = if self.cfg.dense_decode {
                let bucket = self.sched.decode_bucket(group.len());
                let max_ctx = group
                    .iter()
                    .map(|&i| self.active[i].context)
                    .max()
                    .unwrap_or(1)
                    .max(1);
                (
                    decode_step_tflops_dense(&self.cfg.e2e, bucket, max_ctx, max_ctx),
                    kv_read_bytes_dense(&self.cfg.e2e.model, bucket, max_ctx),
                )
            } else {
                let ctxs: Vec<usize> = group
                    .iter()
                    .map(|&i| self.active[i].context.max(1))
                    .collect();
                (
                    decode_group_report_paged(&self.cfg.e2e, &ctxs),
                    kv_read_bytes_paged(&self.cfg.e2e.model, &ctxs),
                )
            };
            let t = rep.time_s;
            let start_s = self.now_s;
            self.now_s += t;
            self.metrics.decode_steps += 1;
            self.metrics.decode_batch_sum += group.len() as u64;
            self.metrics.decode_time.record(t);
            let step = StepStats {
                time_s: t,
                model_flops: rep.model_flops,
                kv_bytes_read: kv_bytes as u64,
                pool_occupancy: self.alloc.utilization(),
            };
            let step_mfu = step.apply(&mut self.metrics, self.cfg.e2e.device.peak_fp8_tflops);
            if let Some(tr) = self.trace.as_mut() {
                tr.record_span(
                    None,
                    start_s,
                    t,
                    TraceEventKind::DecodeStep {
                        batch: group.len(),
                        mfu: step_mfu,
                        kv_bytes: kv_bytes as u64,
                        pool_occupancy: step.pool_occupancy,
                    },
                );
            }
            for &i in &group {
                {
                    let a = &mut self.active[i];
                    a.generated += 1;
                    a.context += 1;
                    a.last_scheduled_s = self.now_s;
                }
                self.metrics.generated_tokens += 1;
                self.metrics.tpot.record(t);
            }
        }
        true
    }

    fn retire_finished(&mut self) {
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].generated >= self.active[i].max_new {
                let a = self.active.swap_remove(i);
                self.alloc
                    .release(a.blocks)
                    // lint:allow(no-unwrap-in-lib): retiring a request frees the block count its admission charged
                    .expect("retire releases exactly the blocks it allocated");
                if a.cache_tokens > 0 {
                    if let Some(p) = self.prefix.as_mut() {
                        p.release(&a.prompt, a.cache_tokens);
                    }
                }
                // A beam group retires as one request: branches release
                // their blocks as they finish, but only the last branch
                // standing emits the output (the engine emits the
                // best-scoring branch; the sim models timing, and all
                // branches share it).
                if a.beam_width > 1 {
                    let group_live = self.active.iter().any(|x| x.id == a.id)
                        || self.preempted.iter().any(|p| p.a.id == a.id);
                    if group_live {
                        continue;
                    }
                    self.metrics.beam_prunes += (a.beam_width - 1) as u64;
                }
                let n = a.generated;
                let tpot_s = if n > 1 {
                    (self.now_s - a.first_token_s) / (n - 1) as f64
                } else {
                    0.0
                };
                let total_s = a.ttft_s + (self.now_s - a.first_token_s);
                if let Some(tr) = self.trace.as_mut() {
                    tr.record_at(
                        self.now_s,
                        Some(a.id),
                        TraceEventKind::Retire {
                            generated: n,
                            ttft_s: a.ttft_s,
                            tpot_s,
                            total_s,
                        },
                    );
                }
                self.finished.push(RequestOutput {
                    id: a.id,
                    prompt_len: a.prompt.len(),
                    // The simulation produces timing, not text.
                    tokens: vec![0; n],
                    ttft_s: a.ttft_s,
                    tpot_s,
                    total_s,
                });
                self.metrics.requests_completed += 1;
            } else {
                i += 1;
            }
        }
        if self.active.is_empty() {
            // The fractional credit is per-stream state: a fresh lone
            // sequence starts its speculation ledger from zero.
            self.spec_credit = 0.0;
        }
    }
}

impl ReplicaHandle for SimReplica {
    fn label(&self) -> String {
        self.label.clone()
    }

    fn clock_s(&self) -> f64 {
        self.now_s
    }

    fn advance_clock_to(&mut self, t_s: f64) {
        if self.active.is_empty() && self.queue.is_empty() && self.preempted.is_empty() {
            self.now_s = self.now_s.max(t_s);
        }
    }

    fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Preempted sequences count as active: they are accepted, resident
    /// work the replica still owes (and `has_work` must keep stepping).
    fn active(&self) -> usize {
        self.active.len() + self.preempted.len()
    }

    fn outstanding_tokens(&self) -> usize {
        let queued: usize = self
            .queue
            .iter()
            .map(|(r, _)| r.prompt.len() + r.max_new_tokens)
            .sum();
        let resident: usize = self
            .active
            .iter()
            .map(|a| a.prompt.len() + a.max_new.saturating_sub(a.generated))
            .sum();
        let parked: usize = self
            .preempted
            .iter()
            .map(|p| p.a.prompt.len() + p.a.max_new.saturating_sub(p.a.generated))
            .sum();
        queued + resident + parked
    }

    fn queue_capacity(&self) -> usize {
        self.cfg.queue_capacity
    }

    fn could_ever_admit(&self, prompt: &[i32], max_new_tokens: usize) -> Admission {
        let prompt_len = prompt.len();
        // Cold starts need a compiled bucket — but a warm prompt whose
        // resident prefix makes the chunked tail worthwhile is served
        // through the decode path and is not bucket-bound. (Screening the
        // warm prompt cold was the ROADMAP's prefix-blindness bug: the
        // router rejected `PromptTooLong` what the replica would happily
        // admit.)
        if self.sched.prefill_bucket(prompt_len).is_none()
            && !warm_admittable_without_bucket(self.prefix.as_ref(), prompt)
        {
            return Admission::PromptTooLong;
        }
        // Every token must still be resident while the request runs —
        // sharing saves bytes across *concurrent* requests, not within one.
        if self.alloc.blocks_for(prompt_len + max_new_tokens) > self.alloc.total_blocks {
            return Admission::KvWouldOom;
        }
        Admission::Accept
    }

    fn cached_prefix_tokens(&self, prompt: &[i32]) -> usize {
        self.prefix.as_ref().map_or(0, |p| p.lookup(prompt))
    }

    fn cached_prefix_bytes(&self) -> usize {
        self.prefix.as_ref().map_or(0, |p| p.cached_bytes())
    }

    fn submit(&mut self, req: Request, arrival_s: f64) -> bool {
        if self.queue.len() >= self.cfg.queue_capacity {
            return false;
        }
        self.queue.push_back((req, arrival_s));
        true
    }

    fn step(&mut self) -> Result<bool> {
        let mut did = self.admit_one_prefill();
        // Single-stream decode goes through the draft-verify fast path
        // when configured; any other shape falls back to plain rounds.
        did |= self.speculative_round() || self.decode_round();
        self.retire_finished();
        if let Some(tr) = self.trace.as_mut() {
            tr.set_virtual_now(self.now_s);
            self.metrics.trace_events_dropped = tr.dropped();
        }
        Ok(did)
    }

    fn take_finished(&mut self) -> Vec<RequestOutput> {
        std::mem::take(&mut self.finished)
    }

    fn evict_queued(&mut self) -> Vec<Request> {
        self.queue.drain(..).map(|(r, _)| r).collect()
    }

    fn abort_active(&mut self) -> Vec<RequestId> {
        let mut ids = Vec::new();
        for a in self.active.drain(..) {
            self.alloc
                .release(a.blocks)
                // lint:allow(no-unwrap-in-lib): aborting a request frees the block count its admission charged
                .expect("abort releases exactly the blocks it allocated");
            if a.cache_tokens > 0 {
                if let Some(p) = self.prefix.as_mut() {
                    p.release(&a.prompt, a.cache_tokens);
                }
            }
            ids.push(a.id);
        }
        for p in self.preempted.drain(..) {
            // Preempted sequences hold no pool blocks and no cache pins;
            // a swap record just vacates its host-tier budget.
            if let Some(host) = self.host.as_mut() {
                host.take(p.a.id);
            }
            ids.push(p.a.id);
        }
        ids
    }

    fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    fn enable_trace(&mut self, replica: usize, capacity: usize) {
        self.trace = Some(TraceRecorder::with_capacity(
            replica,
            Clock::virtual_at(self.now_s),
            capacity,
        ));
    }

    fn trace(&self) -> Option<&TraceRecorder> {
        self.trace.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn replica() -> SimReplica {
        SimReplica::new("sim0", SimReplicaConfig::synthetic_tiny()).unwrap()
    }

    #[test]
    fn single_request_completes_with_virtual_latency() {
        let mut r = replica();
        assert!(r.submit(Request::new(1, vec![0; 24], 8), 0.0));
        let mut outs = Vec::new();
        while r.has_work() {
            r.step().unwrap();
            outs.extend(r.take_finished());
        }
        assert_eq!(outs.len(), 1);
        let o = &outs[0];
        assert_eq!(o.tokens.len(), 8);
        assert!(o.ttft_s > 0.0);
        assert!(o.total_s >= o.ttft_s);
        assert!(r.clock_s() > 0.0);
        assert_eq!(r.metrics().requests_completed, 1);
        // All KV blocks returned.
        assert_eq!(r.allocator().free_blocks(), r.allocator().total_blocks);
    }

    #[test]
    fn batching_interleaves_up_to_slot_limit() {
        let mut r = replica();
        for i in 0..6 {
            assert!(r.submit(Request::new(i, vec![0; 16], 8), 0.0));
        }
        while r.has_work() {
            r.step().unwrap();
        }
        let m = r.metrics();
        assert_eq!(m.requests_completed, 6);
        assert!(
            m.mean_decode_batch() > 1.0,
            "continuous batching never batched: {}",
            m.mean_decode_batch()
        );
    }

    #[test]
    fn admission_checks_report_reasons() {
        let mut cfg = SimReplicaConfig::synthetic_tiny();
        cfg.kv_blocks_override = Some(4); // 4 × 16 = 64 KV tokens total
        cfg.queue_capacity = 1;
        let mut r = SimReplica::new("tiny", cfg).unwrap();
        assert_eq!(r.could_ever_admit(&[0; 16], 8), Admission::Accept);
        assert_eq!(r.could_ever_admit(&[0; 4096], 8), Admission::PromptTooLong);
        assert_eq!(r.could_ever_admit(&[0; 60], 16), Admission::KvWouldOom);
        assert!(r.submit(Request::new(0, vec![0; 16], 4), 0.0));
        assert_eq!(r.can_admit_now(&[0; 16], 4), Admission::QueueFull);
        assert!(!r.submit(Request::new(1, vec![0; 16], 4), 0.0));
    }

    #[test]
    fn oversized_request_drains_instead_of_wedging() {
        // Submitted directly (bypassing router screening), an impossible
        // request must complete empty rather than hang the replica.
        let mut cfg = SimReplicaConfig::synthetic_tiny();
        cfg.kv_blocks_override = Some(2);
        let mut r = SimReplica::new("t", cfg).unwrap();
        assert!(r.submit(Request::new(7, vec![0; 64], 64), 0.0)); // needs 8 blocks
        let mut guard = 0;
        while r.has_work() {
            r.step().unwrap();
            guard += 1;
            assert!(guard < 100, "replica wedged on impossible request");
        }
        let outs = r.take_finished();
        assert_eq!(outs.len(), 1);
        assert!(outs[0].tokens.is_empty());
    }

    #[test]
    fn abort_active_frees_blocks_and_reports_ids() {
        let mut r = replica();
        r.submit(Request::new(5, vec![0; 16], 8), 0.0);
        r.submit(Request::new(6, vec![0; 16], 8), 0.0);
        r.step().unwrap(); // request 5 prefilled (one admission per step)
        assert_eq!(r.active(), 1);
        let total = r.allocator().total_blocks;
        assert!(r.allocator().free_blocks() < total);
        let lost = r.abort_active();
        assert_eq!(lost, vec![5]);
        assert_eq!(r.active(), 0);
        assert_eq!(r.allocator().free_blocks(), total);
        assert_eq!(r.queued(), 1, "queued request 6 untouched");
    }

    #[test]
    fn fp8_kv_quadruples_block_budget_at_equal_bytes() {
        // Same byte budget, different KV dtype: the admission model's
        // capacity follows the shared KvLayout rate (4 B → 1 B per elem).
        let budget = 32.0 * 1024.0 * 1024.0;
        let mk = |dtype: KvDtype| {
            let mut cfg = SimReplicaConfig::synthetic_tiny();
            cfg.kv_dtype = dtype;
            cfg.kv_bytes_budget_override = Some(budget);
            SimReplica::new("dtype", cfg).unwrap()
        };
        let f32r = mk(KvDtype::F32);
        let fp8r = mk(KvDtype::FP8_DEFAULT);
        assert_eq!(
            fp8r.allocator().total_blocks,
            4 * f32r.allocator().total_blocks
        );
    }

    #[test]
    fn idle_clock_jumps_forward_only_when_idle() {
        let mut r = replica();
        r.advance_clock_to(5.0);
        assert_eq!(r.clock_s(), 5.0);
        r.advance_clock_to(2.0);
        assert_eq!(r.clock_s(), 5.0, "clock never goes backwards");
        r.submit(Request::new(1, vec![0; 16], 2), 6.0);
        r.advance_clock_to(100.0);
        assert_eq!(r.clock_s(), 5.0, "busy replica keeps its clock");
        // TTFT counts from the 6.0 s arrival, not from the stale clock.
        while r.has_work() {
            r.step().unwrap();
        }
        let outs = r.take_finished();
        assert!(outs[0].ttft_s > 0.0);
        assert!(r.clock_s() > 6.0);
    }

    #[test]
    fn second_identical_prompt_hits_and_skips_prefill_time() {
        // The paper-geometry replica: at 70B scale prefill FLOPs dominate
        // (on the tiny synthetic model everything is launch-overhead-bound
        // and a cache cannot win — the right regime to measure is the real
        // one). A full hit pays one bootstrap decode step instead of a
        // 1024-token bucketed prefill.
        let mut cfg = SimReplicaConfig::gaudi2_llama31_70b();
        cfg.prefix_cache = true;
        let mut r = SimReplica::new("warm", cfg).unwrap();
        let prompt = vec![3i32; 1024];
        r.submit(Request::new(0, prompt.clone(), 4), 0.0);
        while r.has_work() {
            r.step().unwrap();
        }
        let cold = r.take_finished().remove(0);
        assert_eq!(r.metrics().prefix_misses, 1);
        assert_eq!(r.cached_prefix_tokens(&prompt), 1024);
        assert!(r.cached_prefix_bytes() > 0);

        r.submit(Request::new(1, prompt.clone(), 4), r.clock_s());
        while r.has_work() {
            r.step().unwrap();
        }
        let warm = r.take_finished().remove(0);
        assert_eq!(r.metrics().prefix_hits, 1);
        assert_eq!(r.metrics().prefix_hit_tokens, 1024);
        assert!(
            warm.ttft_s < cold.ttft_s / 2.0,
            "warm TTFT {:.6}s must be ≥2x faster than cold {:.6}s",
            warm.ttft_s,
            cold.ttft_s
        );
        // Everything is released: only the cache still holds blocks.
        let held = r.prefix_cache().unwrap().cached_blocks();
        assert_eq!(
            r.allocator().free_blocks() + held,
            r.allocator().total_blocks
        );
        assert_eq!(r.prefix_cache().unwrap().total_refs(), 0);
    }

    #[test]
    fn shared_prefix_admits_concurrently_under_tight_budget() {
        // Two requests sharing a 512-token prompt, under a pool that holds
        // 48 blocks (768 tokens). Each needs blocks_for(512 + 16) = 33:
        // without the cache the second request cannot be resident until the
        // first retires; with it, the shared prefix is charged once and
        // both run concurrently.
        let mk = |prefix_cache: bool| {
            let mut cfg = SimReplicaConfig::synthetic_tiny();
            cfg.prefix_cache = prefix_cache;
            cfg.kv_blocks_override = Some(48);
            SimReplica::new("tight", cfg).unwrap()
        };
        let prompt = vec![9i32; 512];
        for (with_cache, expect_concurrent) in [(false, false), (true, true)] {
            let mut r = mk(with_cache);
            r.submit(Request::new(0, prompt.clone(), 16), 0.0);
            r.submit(Request::new(1, prompt.clone(), 16), 0.0);
            r.step().unwrap();
            assert_eq!(r.active(), 1, "first request admitted");
            r.step().unwrap();
            assert_eq!(
                r.active() == 2,
                expect_concurrent,
                "prefix_cache={with_cache}: concurrent admission mismatch"
            );
            while r.has_work() {
                r.step().unwrap();
            }
            assert_eq!(r.metrics().requests_completed, 2);
            // No leaked blocks either way.
            let held = r.prefix_cache().map_or(0, |p| p.cached_blocks());
            assert_eq!(
                r.allocator().free_blocks() + held,
                r.allocator().total_blocks
            );
        }
    }

    #[test]
    fn paged_decode_prices_actual_contexts_not_the_group_max() {
        // Paper geometry, a ragged pair (one long, one short prompt)
        // decoding together: the dense reference pads both bucket rows to
        // the group-max context, the paged path charges each row's live
        // blocks — the same workload must finish strictly sooner paged.
        let mk = |dense: bool| {
            let mut cfg = SimReplicaConfig::gaudi2_llama31_70b();
            cfg.dense_decode = dense;
            let mut r = SimReplica::new(if dense { "dense" } else { "paged" }, cfg).unwrap();
            r.submit(Request::new(0, vec![1i32; 4096], 16), 0.0);
            r.submit(Request::new(1, vec![2i32; 512], 16), 0.0);
            while r.has_work() {
                r.step().unwrap();
            }
            assert_eq!(r.metrics().requests_completed, 2);
            r.clock_s()
        };
        let paged = mk(false);
        let dense = mk(true);
        assert!(
            paged < dense,
            "paged makespan {paged} must beat dense-copy {dense}"
        );
    }

    #[test]
    fn admission_pressure_evicts_unreferenced_cache_blocks() {
        // Pool of 40 blocks. A 512-token prompt leaves 32 blocks cached
        // after retiring; a *different* 512-token prompt then needs 33
        // blocks cold — admission must evict the stale cached prefix to
        // make room rather than waiting forever.
        let mut cfg = SimReplicaConfig::synthetic_tiny();
        cfg.prefix_cache = true;
        cfg.kv_blocks_override = Some(40);
        let mut r = SimReplica::new("evict", cfg).unwrap();
        r.submit(Request::new(0, vec![1i32; 512], 8), 0.0);
        while r.has_work() {
            r.step().unwrap();
        }
        assert_eq!(r.prefix_cache().unwrap().cached_blocks(), 32);
        r.submit(Request::new(1, vec![2i32; 512], 8), 0.0);
        while r.has_work() {
            r.step().unwrap();
        }
        assert_eq!(r.metrics().requests_completed, 2);
        assert!(r.metrics().prefix_evicted_blocks > 0, "eviction must fire");
        let held = r.prefix_cache().unwrap().cached_blocks();
        assert_eq!(
            r.allocator().free_blocks() + held,
            r.allocator().total_blocks
        );
    }

    fn drain(r: &mut SimReplica) -> Vec<RequestOutput> {
        let mut outs = Vec::new();
        let mut guard = 0;
        while r.has_work() {
            r.step().unwrap();
            outs.extend(r.take_finished());
            guard += 1;
            assert!(guard < 20_000, "replica wedged under preemption");
        }
        outs
    }

    #[test]
    fn preemption_completes_overload_without_losing_requests() {
        // 8 requests × blocks_for(32+32) = 4 blocks of lifetime footprint
        // each, against a 10-block pool: the legacy up-front charge holds
        // at most 3 concurrently; the tier admits on the prompt footprint
        // and preempts its way through decode growth.
        let mut cfg = SimReplicaConfig::synthetic_tiny();
        cfg.kv_blocks_override = Some(10);
        cfg.slots = 8;
        cfg.host_kv_bytes = 1e9;
        cfg.preempt_policy = PreemptPolicy::Swap;
        let mut r = SimReplica::new("overload", cfg).unwrap();
        for i in 0..8 {
            assert!(r.submit(Request::new(i, vec![1; 32], 32), 0.0));
        }
        let outs = drain(&mut r);
        assert_eq!(outs.len(), 8, "zero lost requests under overload");
        for o in &outs {
            assert_eq!(o.tokens.len(), 32, "request {} lost tokens", o.id);
        }
        let m = r.metrics();
        assert!(m.preemptions > 0, "a tight pool must preempt");
        assert!(m.swapped_out_blocks > 0, "swap policy must use the tier");
        assert_eq!(
            m.swapped_in_blocks, m.swapped_out_blocks,
            "every swapped-out block must come back"
        );
        assert!(m.host_swap_bytes > 0);
        assert_eq!(m.recompute_resumes, 0, "swap policy never re-prefills");
        // All state fully unwound.
        assert_eq!(r.allocator().free_blocks(), r.allocator().total_blocks);
        assert!(r.host_tier().unwrap().is_empty());
    }

    #[test]
    fn recompute_policy_drops_blocks_and_reprefills() {
        let mut cfg = SimReplicaConfig::synthetic_tiny();
        cfg.kv_blocks_override = Some(8);
        cfg.slots = 6;
        cfg.host_kv_bytes = 1e9;
        cfg.preempt_policy = PreemptPolicy::Recompute;
        let mut r = SimReplica::new("recompute", cfg).unwrap();
        for i in 0..6 {
            assert!(r.submit(Request::new(i, vec![2; 32], 24), 0.0));
        }
        let outs = drain(&mut r);
        assert_eq!(outs.len(), 6);
        let m = r.metrics();
        assert!(m.preemptions > 0);
        assert!(m.recompute_resumes > 0, "recompute resumes must fire");
        assert_eq!(m.swapped_out_blocks, 0, "recompute never touches the tier");
        assert_eq!(m.host_swap_bytes, 0);
        assert_eq!(r.allocator().free_blocks(), r.allocator().total_blocks);
        assert!(r.host_tier().unwrap().is_empty());
    }

    #[test]
    fn auto_falls_back_to_recompute_when_the_tier_is_full() {
        let mut cfg = SimReplicaConfig::synthetic_tiny();
        cfg.kv_blocks_override = Some(8);
        cfg.slots = 6;
        cfg.host_kv_bytes = 1.0; // a one-byte tier holds no block
        cfg.preempt_policy = PreemptPolicy::Auto;
        let mut r = SimReplica::new("tiny-tier", cfg).unwrap();
        for i in 0..6 {
            assert!(r.submit(Request::new(i, vec![3; 32], 24), 0.0));
        }
        let outs = drain(&mut r);
        assert_eq!(outs.len(), 6);
        let m = r.metrics();
        assert!(m.preemptions > 0);
        assert_eq!(m.swapped_out_blocks, 0, "nothing fits a one-byte tier");
        assert!(m.recompute_resumes > 0, "auto must fall back to recompute");
    }

    #[test]
    fn auto_swaps_when_transfer_beats_reprefill_at_scale() {
        // 70B geometry: a ~65-block (~170 MB) PCIe round trip costs ~10 ms
        // while re-prefilling a 1k-token context costs >100 ms — auto must
        // always choose the link.
        let mut cfg = SimReplicaConfig::gaudi2_llama31_70b();
        cfg.kv_blocks_override = Some(140);
        cfg.slots = 4;
        cfg.host_kv_bytes = 2e9;
        cfg.preempt_policy = PreemptPolicy::Auto;
        let mut r = SimReplica::new("auto70b", cfg).unwrap();
        for i in 0..4 {
            assert!(r.submit(Request::new(i, vec![7; 1024], 64), 0.0));
        }
        let outs = drain(&mut r);
        assert_eq!(outs.len(), 4);
        let m = r.metrics();
        assert!(m.preemptions > 0, "140 blocks cannot hold 4×69 residents");
        assert!(m.swapped_out_blocks > 0);
        assert_eq!(
            m.recompute_resumes, 0,
            "at 70B geometry the PCIe round trip always beats re-prefill"
        );
    }

    #[test]
    fn tier_off_never_preempts_and_stays_legacy_exact() {
        // The same tight-pool workload with the tier off serializes via
        // the legacy wait-for-retire path: zero preemption machinery.
        let mut cfg = SimReplicaConfig::synthetic_tiny();
        cfg.kv_blocks_override = Some(10);
        cfg.slots = 8;
        let mut r = SimReplica::new("legacy", cfg).unwrap();
        for i in 0..8 {
            assert!(r.submit(Request::new(i, vec![1; 32], 32), 0.0));
        }
        let outs = drain(&mut r);
        assert_eq!(outs.len(), 8);
        let m = r.metrics();
        assert_eq!(m.preemptions, 0);
        assert_eq!(m.swapped_out_blocks + m.swapped_in_blocks, 0);
        assert_eq!(m.host_swap_bytes, 0);
        assert!(r.host_tier().is_none());
    }

    #[test]
    fn prefix_snapshot_restores_warm_ttft_across_restart() {
        let mut cfg = SimReplicaConfig::gaudi2_llama31_70b();
        cfg.prefix_cache = true;
        let mut r = SimReplica::new("gen0", cfg.clone()).unwrap();
        let prompt = vec![3i32; 1024];
        r.submit(Request::new(0, prompt.clone(), 4), 0.0);
        let cold = drain(&mut r).remove(0);
        let snap = r.snapshot_prefixes();
        assert!(!snap.is_empty(), "the hot prompt must be exported");
        // Restart: a fresh replica (new process, empty HBM) reloads the
        // host-persisted subtrees and serves the repeat prompt warm.
        let mut r2 = SimReplica::new("gen1", cfg).unwrap();
        assert_eq!(r2.restore_prefixes(&snap), 1024);
        assert_eq!(r2.cached_prefix_tokens(&prompt), 1024);
        r2.submit(Request::new(1, prompt.clone(), 4), 0.0);
        let warm = drain(&mut r2).remove(0);
        assert!(
            warm.ttft_s < cold.ttft_s / 2.0,
            "restored cache must serve warm: {} vs {}",
            warm.ttft_s,
            cold.ttft_s
        );
        // The restored cache is pool-charged at the usual block rate.
        let held = r2.prefix_cache().unwrap().cached_blocks();
        assert_eq!(
            r2.allocator().free_blocks() + held,
            r2.allocator().total_blocks
        );
    }

    #[test]
    fn abort_under_preemption_reports_parked_ids_and_frees_everything() {
        let mut cfg = SimReplicaConfig::synthetic_tiny();
        cfg.kv_blocks_override = Some(6);
        cfg.slots = 4;
        cfg.host_kv_bytes = 1e9;
        cfg.preempt_policy = PreemptPolicy::Swap;
        let mut r = SimReplica::new("abort", cfg).unwrap();
        for i in 0..4 {
            assert!(r.submit(Request::new(i, vec![4; 32], 32), 0.0));
        }
        // Step until something is parked in the tier.
        let mut guard = 0;
        while r.metrics().preemptions == 0 && r.has_work() {
            r.step().unwrap();
            guard += 1;
            assert!(guard < 1000, "never preempted");
        }
        let preempted_now = r.preempted.len();
        assert!(preempted_now > 0);
        let mut ids = r.abort_active();
        assert!(ids.len() >= preempted_now, "parked ids must be reported");
        let n = ids.len();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n, "no duplicate ids");
        assert_eq!(r.active(), 0);
        assert_eq!(r.allocator().free_blocks(), r.allocator().total_blocks);
        assert!(r.host_tier().unwrap().is_empty());
    }

    #[test]
    fn speculative_single_stream_beats_plain_decode() {
        // 70B paper geometry, one long single-stream request: draft-verify
        // at γ=4 / α=0.8 must cut TPOT well below token-by-token decode
        // (the tiny draft's rounds are nearly free next to a 70B step).
        let mk = |gamma: usize| {
            let mut cfg = SimReplicaConfig::gaudi2_llama31_70b();
            cfg.spec_gamma = gamma;
            cfg.spec_acceptance = 0.8;
            let mut r = SimReplica::new("spec", cfg).unwrap();
            r.submit(Request::new(0, vec![1i32; 1024], 64), 0.0);
            let outs = drain(&mut r);
            assert_eq!(outs.len(), 1);
            assert_eq!(outs[0].tokens.len(), 64, "no tokens lost to rollback");
            assert_eq!(r.allocator().free_blocks(), r.allocator().total_blocks);
            (outs[0].tpot_s, r.metrics().clone())
        };
        let (plain_tpot, plain_m) = mk(0);
        assert_eq!(plain_m.spec_rounds, 0, "γ=0 means speculation is off");
        let (spec_tpot, m) = mk(4);
        assert!(m.spec_rounds > 0, "speculative rounds must fire");
        // Every decoded token came through a verify round: prefill's first
        // token plus each round's accepted prefix + bonus/correction.
        assert_eq!(
            m.spec_accepted_tokens + m.spec_rounds + 1,
            m.generated_tokens
        );
        assert_eq!(m.spec_rejected_tokens, 4 * m.spec_rounds - m.spec_accepted_tokens);
        // Accept-prefix geometry: E[accepted]/γ < α (a miss forfeits the
        // tail), but well above the α→0 floor.
        let rate = m.spec_acceptance_rate();
        assert!((0.4..0.8).contains(&rate), "acceptance rate {rate}");
        assert!(
            plain_tpot / spec_tpot > 1.5,
            "γ=4/α=0.8 speedup: plain {plain_tpot} vs spec {spec_tpot}"
        );
    }

    #[test]
    fn speculative_zero_acceptance_still_progresses() {
        // α=0: every round rejects the whole draft and keeps only the
        // target's correction token — forward progress never stalls and
        // every round is a rollback.
        let mut cfg = SimReplicaConfig::synthetic_tiny();
        cfg.spec_gamma = 2;
        cfg.spec_acceptance = 0.0;
        let mut r = SimReplica::new("spec0", cfg).unwrap();
        r.submit(Request::new(0, vec![0; 32], 8), 0.0);
        let outs = drain(&mut r);
        assert_eq!(outs[0].tokens.len(), 8);
        let m = r.metrics();
        assert_eq!(m.spec_rounds, 7, "one correction token per round");
        assert_eq!(m.spec_accepted_tokens, 0);
        assert_eq!(m.spec_rollbacks, m.spec_rounds);
        assert_eq!(m.spec_acceptance_rate(), 0.0);
        assert_eq!(r.allocator().free_blocks(), r.allocator().total_blocks);
    }

    #[test]
    fn speculation_steps_aside_for_batches() {
        // With two sequences resident the batch already amortizes the
        // per-step overhead, so the spec fast path must not fire — but
        // solo phases (before the second admission, after the first
        // retire) still speculate.
        let mut cfg = SimReplicaConfig::synthetic_tiny();
        cfg.spec_gamma = 4;
        let mut r = SimReplica::new("specbatch", cfg).unwrap();
        r.submit(Request::new(0, vec![0; 16], 24), 0.0);
        r.submit(Request::new(1, vec![0; 16], 24), 0.0);
        let mut guard = 0;
        while r.has_work() {
            let paired = r.active.len() == 2;
            let before = r.metrics().spec_rounds;
            r.step().unwrap();
            if paired {
                assert_eq!(
                    r.metrics().spec_rounds,
                    before,
                    "no speculative rounds while two sequences are resident"
                );
            }
            guard += 1;
            assert!(guard < 1000);
        }
        assert_eq!(r.metrics().requests_completed, 2);
        assert!(r.metrics().spec_rounds > 0, "solo phases must speculate");
    }

    #[test]
    fn beam_group_retires_once_with_fork_accounting() {
        let mut cfg = SimReplicaConfig::synthetic_tiny();
        cfg.beam_width = 3;
        let mut r = SimReplica::new("beam", cfg).unwrap();
        r.submit(Request::new(9, vec![0; 32], 8), 0.0);
        let outs = drain(&mut r);
        assert_eq!(outs.len(), 1, "a beam group emits one output");
        assert_eq!(outs[0].tokens.len(), 8);
        let m = r.metrics();
        assert_eq!(m.requests_completed, 1);
        assert_eq!(m.beam_forks, 2);
        assert_eq!(m.beam_prunes, 2);
        // Branches decode together as a continuous batch.
        assert!(m.mean_decode_batch() > 1.0);
        // First token per branch, then 7 more each.
        assert_eq!(m.generated_tokens, 24);
        assert_eq!(r.allocator().free_blocks(), r.allocator().total_blocks);
    }

    #[test]
    fn beam_width_degrades_to_fit_slots_and_pool() {
        // 2 slots: a width-8 request degrades to width 2 instead of
        // wedging; per-request override beats the config default.
        let mut cfg = SimReplicaConfig::synthetic_tiny();
        cfg.slots = 2;
        cfg.beam_width = 1;
        let mut r = SimReplica::new("beamfit", cfg).unwrap();
        r.submit(Request::new(3, vec![0; 16], 4).with_beam_width(8), 0.0);
        let outs = drain(&mut r);
        assert_eq!(outs.len(), 1);
        assert_eq!(r.metrics().beam_forks, 1, "width clamped to the 2 slots");
        assert_eq!(r.metrics().beam_prunes, 1);
        assert_eq!(r.allocator().free_blocks(), r.allocator().total_blocks);
    }
}
