//! Routing policies: which replica gets the next request.
//!
//! Three policies, mirroring what production LLM routers deploy:
//!
//! * **RoundRobin** — cycle through replicas regardless of load. Baseline;
//!   degrades badly when request costs are skewed.
//! * **LeastOutstandingTokens** — send to the replica with the fewest
//!   prompt+budget tokens queued or resident, minus the prompt tokens its
//!   prefix cache would serve for free. Token-weighted least-loaded with a
//!   warmth credit: the natural load signal for LLM serving (a 4k-token
//!   prompt is not one unit of work, and a cached one is nearly none).
//! * **SessionAffinity** — hash the session id (or the prompt's first K
//!   tokens, a prefix-cache key) to a sticky replica, so multi-turn
//!   requests land where their KV/prefix history lives; spill to
//!   least-outstanding when the sticky replica is full, re-pin when it has
//!   been drained or lost.

use std::collections::HashMap;

use crate::coordinator::Request;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastOutstandingTokens,
    SessionAffinity {
        /// Prompt tokens hashed for the affinity key when the request
        /// carries no explicit session id.
        prefix_tokens: usize,
    },
}

impl RoutePolicy {
    /// CLI-friendly parse: "rr", "least", "affinity" (and synonyms).
    pub fn parse(s: &str) -> Option<RoutePolicy> {
        match s {
            "rr" | "round-robin" | "round_robin" => Some(RoutePolicy::RoundRobin),
            "lot" | "least" | "least-outstanding" | "least_outstanding" => {
                Some(RoutePolicy::LeastOutstandingTokens)
            }
            "affinity" | "session" | "session-affinity" | "session_affinity" => {
                Some(RoutePolicy::SessionAffinity { prefix_tokens: 16 })
            }
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round_robin",
            RoutePolicy::LeastOutstandingTokens => "least_outstanding",
            RoutePolicy::SessionAffinity { .. } => "session_affinity",
        }
    }
}

/// One routable replica's load snapshot, as seen by the picker.
#[derive(Clone, Debug)]
pub struct ReplicaView {
    pub id: usize,
    pub outstanding_tokens: usize,
    /// Prompt tokens of the request being routed that this replica could
    /// serve from its prefix cache ("warmth"): those tokens cost it no
    /// prefill, so they are credited against its load.
    pub cached_prefix_tokens: usize,
    /// Whether the replica would accept a submit right now.
    pub admissible: bool,
}

/// Mutable picker state carried across decisions.
#[derive(Debug, Default)]
pub struct PolicyState {
    rr_cursor: usize,
    affinity: HashMap<u64, usize>,
}

impl PolicyState {
    /// Number of sessions currently pinned (diagnostics).
    pub fn pinned_sessions(&self) -> usize {
        self.affinity.len()
    }
}

/// FNV-1a over the token stream — deterministic across runs (unlike
/// `DefaultHasher` we owe reproducible routing to the benches).
pub fn fnv1a(tokens: &[i32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

/// The affinity key: explicit session id, else a prefix hash over the
/// prompt's first `prefix_tokens` tokens. Set `prefix_tokens` to the
/// prefix-cache block size (`PrefixCache::block_tokens`, 16 by default)
/// and two prompts get the same key exactly when the radix tree would
/// share their first block — session stickiness then lands requests where
/// their cached prefix already lives.
pub fn affinity_key(req: &Request, prefix_tokens: usize) -> u64 {
    req.session
        .unwrap_or_else(|| fnv1a(&req.prompt[..req.prompt.len().min(prefix_tokens)]))
}

/// Marginal cost of routing the request here: the replica's outstanding
/// load minus the prompt tokens its prefix cache would serve for free.
/// (The request's own work is constant across replicas, so ranking by
/// `outstanding − cached` orders replicas by completion-time impact.)
fn effective_load(v: &ReplicaView) -> usize {
    v.outstanding_tokens.saturating_sub(v.cached_prefix_tokens)
}

fn least_outstanding(views: &[ReplicaView]) -> Option<usize> {
    views
        .iter()
        .filter(|v| v.admissible)
        .min_by_key(|v| (effective_load(v), v.id))
        .map(|v| v.id)
}

impl RoutePolicy {
    /// Choose a replica id among the admissible views, or None when nothing
    /// can take the request right now. `n_replicas` is the registry size
    /// (round-robin cycles over ids even when some are missing from
    /// `views`, so a drained replica does not skew the rotation).
    pub fn pick(
        &self,
        state: &mut PolicyState,
        views: &[ReplicaView],
        n_replicas: usize,
        req: &Request,
    ) -> Option<usize> {
        match *self {
            RoutePolicy::RoundRobin => {
                let n = n_replicas.max(1);
                let cursor = state.rr_cursor % n;
                let mut best: Option<(usize, usize)> = None;
                for v in views.iter().filter(|v| v.admissible) {
                    let key = (v.id + n - cursor) % n;
                    let better = match best {
                        None => true,
                        Some((bk, _)) => key < bk,
                    };
                    if better {
                        best = Some((key, v.id));
                    }
                }
                let (_, id) = best?;
                state.rr_cursor = (id + 1) % n;
                Some(id)
            }
            RoutePolicy::LeastOutstandingTokens => least_outstanding(views),
            RoutePolicy::SessionAffinity { prefix_tokens } => {
                let key = affinity_key(req, prefix_tokens);
                if let Some(&pinned) = state.affinity.get(&key) {
                    if let Some(v) = views.iter().find(|v| v.id == pinned) {
                        if v.admissible {
                            return Some(pinned);
                        }
                        // Sticky replica is full: spill this request without
                        // moving the session pin.
                        return least_outstanding(views);
                    }
                    // Sticky replica drained or down — fall through, re-pin.
                }
                let id = least_outstanding(views)?;
                state.affinity.insert(key, id);
                Some(id)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn views(loads: &[usize]) -> Vec<ReplicaView> {
        loads
            .iter()
            .enumerate()
            .map(|(id, &outstanding_tokens)| ReplicaView {
                id,
                outstanding_tokens,
                cached_prefix_tokens: 0,
                admissible: true,
            })
            .collect()
    }

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2, 3, 4], 8)
    }

    #[test]
    fn parse_and_labels() {
        assert_eq!(RoutePolicy::parse("rr"), Some(RoutePolicy::RoundRobin));
        assert_eq!(
            RoutePolicy::parse("least"),
            Some(RoutePolicy::LeastOutstandingTokens)
        );
        assert!(matches!(
            RoutePolicy::parse("affinity"),
            Some(RoutePolicy::SessionAffinity { .. })
        ));
        assert_eq!(RoutePolicy::parse("nope"), None);
        assert_eq!(RoutePolicy::RoundRobin.label(), "round_robin");
    }

    #[test]
    fn round_robin_cycles() {
        let p = RoutePolicy::RoundRobin;
        let mut st = PolicyState::default();
        let v = views(&[0, 0, 0]);
        let picks: Vec<usize> = (0..6).map(|i| p.pick(&mut st, &v, 3, &req(i)).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn round_robin_skips_inadmissible() {
        let p = RoutePolicy::RoundRobin;
        let mut st = PolicyState::default();
        let mut v = views(&[0, 0, 0]);
        v[1].admissible = false;
        let picks: Vec<usize> = (0..4).map(|i| p.pick(&mut st, &v, 3, &req(i)).unwrap()).collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
        v[0].admissible = false;
        v[2].admissible = false;
        assert_eq!(p.pick(&mut st, &v, 3, &req(9)), None);
    }

    #[test]
    fn least_outstanding_prefers_lightest() {
        let p = RoutePolicy::LeastOutstandingTokens;
        let mut st = PolicyState::default();
        assert_eq!(p.pick(&mut st, &views(&[50, 10, 30]), 3, &req(0)), Some(1));
        // Tie breaks to the lowest id.
        assert_eq!(p.pick(&mut st, &views(&[10, 10, 30]), 3, &req(1)), Some(0));
    }

    #[test]
    fn least_outstanding_credits_warm_prefix_caches() {
        let p = RoutePolicy::LeastOutstandingTokens;
        let mut st = PolicyState::default();
        // Replica 2 is busier but holds 64 of the prompt's tokens warm:
        // effective load 90 − 64 = 26 beats replica 1's 80.
        let mut v = views(&[100, 80, 90]);
        v[2].cached_prefix_tokens = 64;
        assert_eq!(p.pick(&mut st, &v, 3, &req(0)), Some(2));
        // The credit saturates: warmth beyond the load cannot go negative.
        v[0].cached_prefix_tokens = 1_000_000;
        assert_eq!(p.pick(&mut st, &v, 3, &req(1)), Some(0));
    }

    #[test]
    fn session_affinity_sticks_and_spills() {
        let p = RoutePolicy::SessionAffinity { prefix_tokens: 16 };
        let mut st = PolicyState::default();
        let r = req(0).with_session(77);
        // First pick goes least-outstanding and pins.
        let mut v = views(&[50, 10, 30]);
        assert_eq!(p.pick(&mut st, &v, 3, &r), Some(1));
        assert_eq!(st.pinned_sessions(), 1);
        // Stays pinned even when load shifts.
        v = views(&[0, 100, 0]);
        assert_eq!(p.pick(&mut st, &v, 3, &r), Some(1));
        // Full sticky replica: spill this request, keep the pin.
        v[1].admissible = false;
        assert_eq!(p.pick(&mut st, &v, 3, &r), Some(0));
        v[1].admissible = true;
        assert_eq!(p.pick(&mut st, &v, 3, &r), Some(1));
        // Sticky replica gone from the views (drained): re-pin elsewhere.
        let v2 = vec![
            ReplicaView {
                id: 0,
                outstanding_tokens: 5,
                cached_prefix_tokens: 0,
                admissible: true,
            },
            ReplicaView {
                id: 2,
                outstanding_tokens: 1,
                cached_prefix_tokens: 0,
                admissible: true,
            },
        ];
        assert_eq!(p.pick(&mut st, &v2, 3, &r), Some(2));
        assert_eq!(p.pick(&mut st, &v2, 3, &r), Some(2), "new pin is sticky");
    }

    #[test]
    fn prefix_hash_groups_identical_prefixes() {
        let p = RoutePolicy::SessionAffinity { prefix_tokens: 4 };
        let mut st = PolicyState::default();
        let mut a = Request::new(0, vec![9, 9, 9, 9, 1, 2], 8);
        let mut b = Request::new(1, vec![9, 9, 9, 9, 3, 4], 8);
        a.session = None;
        b.session = None;
        let v = views(&[0, 0]);
        let pa = p.pick(&mut st, &v, 2, &a).unwrap();
        let pb = p.pick(&mut st, &v, 2, &b).unwrap();
        assert_eq!(pa, pb, "same 4-token prefix must share a replica");
        assert_ne!(fnv1a(&[1, 2]), fnv1a(&[2, 1]));
    }
}
