//! Fleet-aggregated serving metrics: per-replica snapshots plus a merged
//! view (TTFT/TPOT percentiles over every replica's samples, total token
//! throughput over the fleet makespan).
//!
//! Rejections are counted per [`RejectReason`] label so the Prometheus
//! exposition can render one zero-filled
//! `repro_fleet_rejected_reason_total{reason=...}` sample for every label
//! in [`RejectReason::ALL_LABELS`] — a reason that never fires still
//! exists as a series, which is what alerting rules need.

use super::queue::RejectReason;
use super::registry::{ReplicaRegistry, ReplicaState};
use super::RejectedRequest;
use crate::coordinator::{LatencyStat, ServeMetrics};

/// One replica's end-of-run snapshot.
#[derive(Clone, Debug)]
pub struct ReplicaReport {
    pub id: usize,
    pub label: String,
    pub state: ReplicaState,
    pub dispatched: u64,
    pub completed: u64,
    pub generated_tokens: u64,
    pub clock_s: f64,
    pub ttft: LatencyStat,
    pub tpot: LatencyStat,
}

/// Aggregated fleet metrics for a finished (or in-progress) run.
#[derive(Clone, Debug)]
pub struct FleetMetrics {
    pub replicas: Vec<ReplicaReport>,
    /// Every replica's counters and latency samples folded together.
    pub merged: ServeMetrics,
    pub rejected: usize,
    /// `rejected` split by [`RejectReason::label`], indexed in
    /// [`RejectReason::ALL_LABELS`] order (zero-filled: every label has a
    /// slot whether or not it fired).
    pub rejected_by_reason: [usize; RejectReason::ALL_LABELS.len()],
    /// Deepest the fleet backlog queue got.
    pub queued_peak: usize,
    /// Latest replica clock — the virtual wall time of the whole run.
    pub makespan_s: f64,
}

impl FleetMetrics {
    pub fn collect(
        registry: &ReplicaRegistry,
        rejected: &[RejectedRequest],
        queued_peak: usize,
    ) -> Self {
        let mut rejected_by_reason = [0usize; RejectReason::ALL_LABELS.len()];
        for r in rejected {
            let label = r.reason.label();
            if let Some(i) = RejectReason::ALL_LABELS.iter().position(|l| *l == label) {
                rejected_by_reason[i] += 1;
            }
        }
        let mut replicas = Vec::with_capacity(registry.len());
        let mut makespan: f64 = 0.0;
        for e in registry.entries() {
            let m = e.handle.metrics();
            let clock = e.handle.clock_s();
            makespan = makespan.max(clock);
            replicas.push(ReplicaReport {
                id: e.id,
                label: e.handle.label(),
                state: e.state,
                dispatched: e.dispatched,
                completed: m.requests_completed,
                generated_tokens: m.generated_tokens,
                clock_s: clock,
                ttft: m.ttft.clone(),
                tpot: m.tpot.clone(),
            });
        }
        // One n-way merge (not chained pairwise) so every replica's latency
        // reservoir is proportionally represented in merged percentiles.
        let all: Vec<&ServeMetrics> = registry.entries().iter().map(|e| e.handle.metrics()).collect();
        let merged = ServeMetrics::merge_many(&all);
        FleetMetrics {
            replicas,
            merged,
            rejected: rejected.len(),
            rejected_by_reason,
            queued_peak,
            makespan_s: makespan,
        }
    }

    /// Fleet token throughput over the run's (virtual) makespan.
    pub fn throughput_tok_s(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.merged.generated_tokens as f64 / self.makespan_s
        } else {
            0.0
        }
    }

    /// Human-readable per-replica + merged summary.
    pub fn report(&self) -> String {
        let mut s = String::new();
        for r in &self.replicas {
            s.push_str(&format!(
                "  replica {:>2} [{}] {:?}: dispatched={} completed={} tokens={} \
                 ttft p50={:.2}ms p99={:.2}ms clock={:.3}s\n",
                r.id,
                r.label,
                r.state,
                r.dispatched,
                r.completed,
                r.generated_tokens,
                r.ttft.p50_s() * 1e3,
                r.ttft.p99_s() * 1e3,
                r.clock_s,
            ));
        }
        s.push_str(&format!(
            "fleet: completed={} rejected={} queued_peak={} tokens={} makespan={:.3}s \
             throughput={:.1} tok/s ttft p50={:.2}ms p95={:.2}ms p99={:.2}ms \
             tpot p50={:.3}ms p99={:.3}ms",
            self.merged.requests_completed,
            self.rejected,
            self.queued_peak,
            self.merged.generated_tokens,
            self.makespan_s,
            self.throughput_tok_s(),
            self.merged.ttft.p50_s() * 1e3,
            self.merged.ttft.p95_s() * 1e3,
            self.merged.ttft.p99_s() * 1e3,
            self.merged.tpot.p50_s() * 1e3,
            self.merged.tpot.p99_s() * 1e3,
        ));
        if self.merged.mfu.count > 0 {
            s.push_str(&format!(
                " mfu p50={:.4} p99={:.4} pool_peak={:.3}",
                self.merged.mfu.p50_s(),
                self.merged.mfu.p99_s(),
                self.merged.pool_occupancy_peak,
            ));
        }
        if self.merged.preemptions > 0 {
            s.push_str(&format!(
                "\noverload: preemptions={} swapped_out={} swapped_in={} \
                 host_swap_bytes={} recompute_resumes={}",
                self.merged.preemptions,
                self.merged.swapped_out_blocks,
                self.merged.swapped_in_blocks,
                self.merged.host_swap_bytes,
                self.merged.recompute_resumes,
            ));
        }
        if self.merged.spec_rounds > 0 || self.merged.beam_forks > 0 {
            s.push_str(&format!(
                "\nspeculative: rounds={} accepted={} rejected={} rollbacks={} \
                 acceptance={:.2} beam_forks={} beam_prunes={}",
                self.merged.spec_rounds,
                self.merged.spec_accepted_tokens,
                self.merged.spec_rejected_tokens,
                self.merged.spec_rollbacks,
                self.merged.spec_acceptance_rate(),
                self.merged.beam_forks,
                self.merged.beam_prunes,
            ));
        }
        if self.rejected > 0 {
            let split: Vec<String> = RejectReason::ALL_LABELS
                .iter()
                .zip(self.rejected_by_reason)
                .filter(|(_, n)| *n > 0)
                .map(|(l, n)| format!("{l}={n}"))
                .collect();
            s.push_str(&format!("\nrejections: {}", split.join(" ")));
        }
        if self.merged.trace_events_dropped > 0 {
            s.push_str(&format!(
                "\nwarning: trace ring buffer dropped {} events across the fleet \
                 (raise --trace-capacity for a complete timeline)",
                self.merged.trace_events_dropped
            ));
        }
        s
    }

    /// Prometheus text exposition for the whole fleet: the merged
    /// [`ServeMetrics`] families plus fleet-level extras (rejections,
    /// backlog peak, makespan, throughput). One scrape = one run snapshot.
    pub fn render_prometheus(&self) -> String {
        let mut s = self.merged.render_prometheus();
        s.push_str("# TYPE repro_fleet_replicas gauge\n");
        s.push_str(&format!("repro_fleet_replicas {}\n", self.replicas.len()));
        s.push_str("# TYPE repro_fleet_rejected_total counter\n");
        s.push_str(&format!("repro_fleet_rejected_total {}\n", self.rejected));
        // Zero-filled per-reason split: every RejectReason label exists as
        // a series even when it never fired this run.
        s.push_str("# TYPE repro_fleet_rejected_reason_total counter\n");
        for (label, n) in RejectReason::ALL_LABELS.iter().zip(self.rejected_by_reason) {
            s.push_str(&format!(
                "repro_fleet_rejected_reason_total{{reason=\"{label}\"}} {n}\n"
            ));
        }
        s.push_str("# TYPE repro_fleet_queued_peak gauge\n");
        s.push_str(&format!("repro_fleet_queued_peak {}\n", self.queued_peak));
        s.push_str("# TYPE repro_fleet_makespan_seconds gauge\n");
        s.push_str(&format!("repro_fleet_makespan_seconds {:.6}\n", self.makespan_s));
        s.push_str("# TYPE repro_fleet_throughput_tokens_per_second gauge\n");
        s.push_str(&format!(
            "repro_fleet_throughput_tokens_per_second {:.3}\n",
            self.throughput_tok_s()
        ));
        s
    }

    /// One JSON object per (replicas, policy) cell — the fig_d bench rows.
    pub fn json_row(&self, replicas: usize, policy: &str, requests: usize) -> String {
        self.json_row_fig("fig_d_fleet_scaling", replicas, policy, requests)
    }

    /// [`Self::json_row`] with the figure id as a parameter, so overload
    /// benches (fig_overload) share one emitter — and one declared schema
    /// — with fleet scaling instead of forking the row format.
    pub fn json_row_fig(&self, fig: &str, replicas: usize, policy: &str, requests: usize) -> String {
        format!(
            "{{\"fig\":\"{}\",\"replicas\":{},\"policy\":\"{}\",\
             \"requests\":{},\"completed\":{},\"rejected\":{},\"generated_tokens\":{},\
             \"makespan_s\":{:.6},\"throughput_tok_s\":{:.3},\
             \"ttft_p50_ms\":{:.4},\"ttft_p95_ms\":{:.4},\"ttft_p99_ms\":{:.4},\
             \"tpot_p50_ms\":{:.5},\"tpot_p95_ms\":{:.5},\"tpot_p99_ms\":{:.5},\
             \"prefix_hits\":{},\"prefix_hit_tokens\":{},\
             \"mfu_mean\":{:.6},\"pool_occupancy_peak\":{:.6},\
             \"trace_events_dropped\":{},\
             \"preemptions\":{},\"swapped_out_blocks\":{},\"swapped_in_blocks\":{},\
             \"host_swap_bytes\":{},\"recompute_resumes\":{},\
             \"spec_rounds\":{},\"spec_accepted_tokens\":{},\
             \"spec_rejected_tokens\":{},\"spec_rollbacks\":{},\
             \"beam_forks\":{},\"beam_prunes\":{}}}",
            fig,
            replicas,
            policy,
            requests,
            self.merged.requests_completed,
            self.rejected,
            self.merged.generated_tokens,
            self.makespan_s,
            self.throughput_tok_s(),
            self.merged.ttft.p50_s() * 1e3,
            self.merged.ttft.p95_s() * 1e3,
            self.merged.ttft.p99_s() * 1e3,
            self.merged.tpot.p50_s() * 1e3,
            self.merged.tpot.p95_s() * 1e3,
            self.merged.tpot.p99_s() * 1e3,
            self.merged.prefix_hits,
            self.merged.prefix_hit_tokens,
            self.merged.mfu.mean_s(),
            self.merged.pool_occupancy_peak,
            self.merged.trace_events_dropped,
            self.merged.preemptions,
            self.merged.swapped_out_blocks,
            self.merged.swapped_in_blocks,
            self.merged.host_swap_bytes,
            self.merged.recompute_resumes,
            self.merged.spec_rounds,
            self.merged.spec_accepted_tokens,
            self.merged.spec_rejected_tokens,
            self.merged.spec_rollbacks,
            self.merged.beam_forks,
            self.merged.beam_prunes,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn rejections(reasons: &[RejectReason]) -> Vec<RejectedRequest> {
        reasons
            .iter()
            .enumerate()
            .map(|(i, r)| RejectedRequest {
                id: i as u64,
                reason: r.clone(),
            })
            .collect()
    }

    #[test]
    fn empty_registry_yields_zeroes() {
        let reg = ReplicaRegistry::new();
        let fm = FleetMetrics::collect(&reg, &[], 0);
        assert!(fm.replicas.is_empty());
        assert_eq!(fm.merged.generated_tokens, 0);
        assert_eq!(fm.throughput_tok_s(), 0.0);
        assert_eq!(fm.rejected_by_reason, [0; RejectReason::ALL_LABELS.len()]);
        assert!(fm.report().contains("fleet:"));
        assert!(!fm.report().contains("rejections:"));
    }

    #[test]
    fn json_row_parses_back() {
        let reg = ReplicaRegistry::new();
        let rej = rejections(&[
            RejectReason::QueueFull { capacity: 8 },
            RejectReason::NoReplicas,
        ]);
        let fm = FleetMetrics::collect(&reg, &rej, 5);
        let row = fm.json_row(4, "least_outstanding", 64);
        let j = Json::parse(&row).expect("bench row must be valid JSON");
        assert_eq!(j.get("replicas").and_then(Json::as_f64), Some(4.0));
        assert_eq!(
            j.get("policy").and_then(Json::as_str),
            Some("least_outstanding")
        );
        assert_eq!(j.get("rejected").and_then(Json::as_f64), Some(2.0));
        // Observability satellites ride in the same row.
        assert_eq!(j.get("mfu_mean").and_then(Json::as_f64), Some(0.0));
        assert_eq!(
            j.get("trace_events_dropped").and_then(Json::as_f64),
            Some(0.0)
        );
        assert!(j.get("pool_occupancy_peak").is_some());
        // Overload counters ride along too (ISSUE 9).
        for key in [
            "preemptions",
            "swapped_out_blocks",
            "swapped_in_blocks",
            "host_swap_bytes",
            "recompute_resumes",
            "spec_rounds",
            "spec_accepted_tokens",
            "spec_rejected_tokens",
            "spec_rollbacks",
            "beam_forks",
            "beam_prunes",
        ] {
            assert_eq!(j.get(key).and_then(Json::as_f64), Some(0.0), "{key}");
        }
        // The parameterized-figure emitter only swaps the fig id.
        let over = fm.json_row_fig("fig_overload", 1, "auto", 64);
        let jo = Json::parse(&over).expect("fig row must be valid JSON");
        assert_eq!(jo.get("fig").and_then(Json::as_str), Some("fig_overload"));
        assert_eq!(jo.get("policy").and_then(Json::as_str), Some("auto"));
    }

    #[test]
    fn prometheus_includes_fleet_families_and_drop_warning() {
        let reg = ReplicaRegistry::new();
        let rej = rejections(&[
            RejectReason::QueueFull { capacity: 4 },
            RejectReason::QueueFull { capacity: 4 },
            RejectReason::KvExhausted { needed_tokens: 99 },
        ]);
        let mut fm = FleetMetrics::collect(&reg, &rej, 7);
        let prom = fm.render_prometheus();
        for needle in [
            "repro_fleet_replicas 0",
            "repro_fleet_rejected_total 3",
            "repro_fleet_queued_peak 7",
            "repro_fleet_makespan_seconds",
            "repro_fleet_throughput_tokens_per_second",
            "repro_ttft_seconds_count",
            // Fired reasons carry their counts...
            "repro_fleet_rejected_reason_total{reason=\"queue_full\"} 2",
            "repro_fleet_rejected_reason_total{reason=\"kv_exhausted\"} 1",
        ] {
            assert!(prom.contains(needle), "missing {needle:?} in:\n{prom}");
        }
        // ...and every label that never fired is still a zero-filled series.
        for label in RejectReason::ALL_LABELS {
            assert!(
                prom.contains(&format!(
                    "repro_fleet_rejected_reason_total{{reason=\"{label}\"}} "
                )),
                "missing zero-filled series for {label:?} in:\n{prom}"
            );
        }
        let rep = fm.report();
        assert!(!rep.contains("warning:"));
        assert!(
            rep.contains("rejections: queue_full=2 kv_exhausted=1"),
            "{rep}"
        );
        fm.merged.trace_events_dropped = 41;
        let rep = fm.report();
        assert!(rep.contains("warning:") && rep.contains("41"), "{rep}");
    }

    #[test]
    fn report_surfaces_preemption_counters_when_present() {
        let reg = ReplicaRegistry::new();
        let mut fm = FleetMetrics::collect(&reg, &[], 0);
        assert!(!fm.report().contains("overload:"));
        fm.merged.preemptions = 4;
        fm.merged.swapped_out_blocks = 12;
        fm.merged.swapped_in_blocks = 12;
        fm.merged.host_swap_bytes = 65_536;
        fm.merged.recompute_resumes = 1;
        let rep = fm.report();
        assert!(
            rep.contains(
                "overload: preemptions=4 swapped_out=12 swapped_in=12 \
                 host_swap_bytes=65536 recompute_resumes=1"
            ),
            "{rep}"
        );
        fm.merged.spec_rounds = 5;
        fm.merged.spec_accepted_tokens = 16;
        fm.merged.spec_rejected_tokens = 4;
        fm.merged.spec_rollbacks = 3;
        fm.merged.beam_forks = 2;
        fm.merged.beam_prunes = 1;
        let rep = fm.report();
        assert!(
            rep.contains(
                "speculative: rounds=5 accepted=16 rejected=4 rollbacks=3 \
                 acceptance=0.80 beam_forks=2 beam_prunes=1"
            ),
            "{rep}"
        );
    }
}
