//! L4 fleet router — multi-replica load balancing over engine replicas.
//!
//! The paper's throughput numbers (Tables 5–6) are per device; serving
//! heavy traffic means many engine replicas behind a router. This module
//! provides that missing layer:
//!
//! * [`ReplicaHandle`] — the narrow interface the router drives engines
//!   through, extracted from [`crate::coordinator::Engine`] (which
//!   implements it) and also implemented by [`SimReplica`], a virtual-time
//!   replica backed by the [`crate::gaudisim`] performance model.
//! * [`ReplicaRegistry`] — fleet membership with Healthy/Draining/Down
//!   state.
//! * [`RoutePolicy`] — round-robin, least-outstanding-tokens, and
//!   session/prefix affinity.
//! * [`FleetQueue`] — bounded fleet-level backlog with typed
//!   [`RejectReason`]s (backpressure, fleet-wide KV OOM, oversized prompt).
//! * [`FleetMetrics`] — per-replica and merged TTFT/TPOT percentiles and
//!   throughput.
//!
//! [`FleetRouter::run_open_loop`] is a discrete-event simulation driver:
//! replicas advance independent virtual clocks, and the router always steps
//! the busy replica whose clock is earliest, delivering arrivals in
//! timestamp order. With wall-clock engines the same loop degenerates to
//! eager dispatch.

pub mod fleet_metrics;
pub mod policy;
pub mod queue;
pub mod registry;
pub mod sim;

pub use fleet_metrics::{FleetMetrics, ReplicaReport};
pub use policy::{affinity_key, fnv1a, PolicyState, ReplicaView, RoutePolicy};
pub use queue::{Admission, FleetQueue, RejectReason, TimedRequest};
pub use registry::{ReplicaEntry, ReplicaRegistry, ReplicaState};
pub use sim::{SimReplica, SimReplicaConfig};

use anyhow::Result;

use crate::coordinator::{Request, RequestId, RequestOutput, ServeMetrics};
use crate::obs::{chrome_trace_json, TraceRecorder};

/// The narrow interface the router drives a replica through.
///
/// Implemented by the real [`crate::coordinator::Engine`] (wall-clock) and
/// by [`SimReplica`] (virtual-clock). All times are seconds on the fleet
/// clock; a wall-clock replica reports elapsed time since construction and
/// ignores clock jumps.
pub trait ReplicaHandle {
    fn label(&self) -> String;

    /// Current position on the fleet clock.
    fn clock_s(&self) -> f64;

    /// Jump an *idle* replica's clock forward to `t_s` (never backwards);
    /// busy and wall-clock replicas ignore this.
    fn advance_clock_to(&mut self, t_s: f64);

    fn queued(&self) -> usize;

    fn active(&self) -> usize;

    fn has_work(&self) -> bool {
        self.queued() + self.active() > 0
    }

    /// Prompt + remaining-generation tokens queued or resident — the load
    /// signal for token-weighted balancing.
    fn outstanding_tokens(&self) -> usize;

    /// Local admission-queue bound.
    fn queue_capacity(&self) -> usize;

    /// Would a submit succeed right now? Provided: feasibility plus room
    /// in the local queue.
    fn can_admit_now(&self, prompt: &[i32], max_new_tokens: usize) -> Admission {
        match self.could_ever_admit(prompt, max_new_tokens) {
            Admission::Accept => {}
            other => return other,
        }
        if self.queued() >= self.queue_capacity() {
            return Admission::QueueFull;
        }
        Admission::Accept
    }

    /// Could this replica serve the request if it were completely idle?
    /// (`KvWouldOom`/`PromptTooLong` here mean "never".) Takes the prompt
    /// itself, not just its length: prefix-aware replicas screen warm
    /// prompts against only their uncached tail, so a prompt longer than
    /// every compiled prefill bucket is still routable to a replica whose
    /// cache holds its prefix.
    fn could_ever_admit(&self, prompt: &[i32], max_new_tokens: usize) -> Admission;

    /// Prompt tokens of `prompt` this replica could serve from its
    /// shared-prefix cache — the "warmth" signal `least` routing credits.
    /// Replicas without a cache report 0.
    fn cached_prefix_tokens(&self, _prompt: &[i32]) -> usize {
        0
    }

    /// Bytes currently resident in this replica's prefix cache (charged at
    /// the shared `KvLayout` rate).
    fn cached_prefix_bytes(&self) -> usize {
        0
    }

    /// Hand over a request that arrived at `arrival_s` on the fleet clock.
    /// Virtual-clock replicas measure TTFT from `arrival_s`; wall-clock
    /// engines ignore it and measure from the request's own creation
    /// `Instant` (for them, dispatch is effectively immediate anyway).
    fn submit(&mut self, req: Request, arrival_s: f64) -> bool;

    /// One scheduling iteration; `Ok(false)` = nothing to do.
    fn step(&mut self) -> Result<bool>;

    fn take_finished(&mut self) -> Vec<RequestOutput>;

    /// Remove and return not-yet-started requests (for re-routing when the
    /// replica is marked down).
    fn evict_queued(&mut self) -> Vec<Request>;

    /// Abandon in-flight (already prefilled) requests, freeing their KV;
    /// returns their ids so the router can account for the loss.
    fn abort_active(&mut self) -> Vec<RequestId>;

    fn metrics(&self) -> &ServeMetrics;

    /// Attach a lifecycle trace recorder; `replica` becomes the Chrome
    /// trace process id, `capacity` bounds the event buffer. Replicas
    /// without tracing support ignore the call (the default).
    fn enable_trace(&mut self, _replica: usize, _capacity: usize) {}

    /// The replica's trace recorder, when tracing is enabled.
    fn trace(&self) -> Option<&TraceRecorder> {
        None
    }
}

/// Fleet-level configuration.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    pub policy: RoutePolicy,
    /// Fleet backlog bound; beyond it requests are rejected (`QueueFull`).
    pub queue_capacity: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            policy: RoutePolicy::LeastOutstandingTokens,
            queue_capacity: 1024,
        }
    }
}

/// A request the fleet refused, with the reason (the "error response").
#[derive(Clone, Debug)]
pub struct RejectedRequest {
    pub id: RequestId,
    pub reason: RejectReason,
}

/// Everything a finished [`FleetRouter::run_open_loop`] produced.
pub struct FleetRunReport {
    pub outputs: Vec<RequestOutput>,
    pub rejected: Vec<RejectedRequest>,
    pub metrics: FleetMetrics,
}

enum TryRoute {
    Dispatched(usize),
    NotNow,
    Reject(RejectReason),
}

/// The fleet router: registry + policy + bounded backlog + event loop.
pub struct FleetRouter {
    pub registry: ReplicaRegistry,
    policy: RoutePolicy,
    policy_state: PolicyState,
    queue: FleetQueue,
    rejected: Vec<RejectedRequest>,
}

impl FleetRouter {
    pub fn new(cfg: FleetConfig) -> Self {
        Self {
            registry: ReplicaRegistry::new(),
            policy: cfg.policy,
            policy_state: PolicyState::default(),
            queue: FleetQueue::new(cfg.queue_capacity),
            rejected: Vec::new(),
        }
    }

    pub fn add_replica(&mut self, handle: Box<dyn ReplicaHandle>) -> usize {
        self.registry.register(handle)
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    pub fn backlog(&self) -> usize {
        self.queue.len()
    }

    pub fn rejected(&self) -> &[RejectedRequest] {
        &self.rejected
    }

    /// Turn on lifecycle tracing for every registered replica (its
    /// registry id becomes the Chrome trace pid).
    pub fn enable_tracing(&mut self, capacity: usize) {
        let ids: Vec<usize> = self.registry.entries().iter().map(|e| e.id).collect();
        for id in ids {
            self.registry.handle_mut(id).enable_trace(id, capacity);
        }
    }

    /// Fleet-wide Chrome trace-event JSON over every tracing replica
    /// (empty trace when tracing was never enabled).
    pub fn chrome_trace(&self) -> String {
        let tracks: Vec<(String, &TraceRecorder)> = self
            .registry
            .entries()
            .iter()
            .filter_map(|e| e.handle.trace().map(|t| (e.handle.label(), t)))
            .collect();
        chrome_trace_json(&tracks)
    }

    /// Health transition. Marking a replica `Down` evicts its queued
    /// backlog into the fleet queue for re-routing (each evicted request
    /// re-enters with `arrival_s` = the replica's clock at eviction, so a
    /// virtual-clock replica's measured TTFT restarts from the failover
    /// point; wall-clock engines keep measuring from the request's
    /// original creation). In-flight requests cannot be migrated — their
    /// KV lived on the dead replica — so they are reported as
    /// `ReplicaFailed` rejections rather than silently lost.
    pub fn set_replica_state(&mut self, id: usize, state: ReplicaState) {
        if state == ReplicaState::Down {
            let at = self.registry.handle(id).clock_s();
            let evicted = self.registry.handle_mut(id).evict_queued();
            for req in evicted {
                self.backlog_or_reject(TimedRequest::new(req, at));
            }
            for lost in self.registry.handle_mut(id).abort_active() {
                self.rejected.push(RejectedRequest {
                    id: lost,
                    reason: RejectReason::ReplicaFailed { replica: id },
                });
            }
        }
        self.registry.set_state(id, state);
    }

    /// Backlog the request, or reject it with `QueueFull` when the fleet
    /// queue is at capacity.
    fn backlog_or_reject(&mut self, tr: TimedRequest) {
        let id = tr.req.id;
        if self.queue.push(tr).is_some() {
            self.rejected.push(RejectedRequest {
                id,
                reason: RejectReason::QueueFull {
                    capacity: self.queue.capacity(),
                },
            });
        }
    }

    pub fn drain_replica(&mut self, id: usize) {
        self.set_replica_state(id, ReplicaState::Draining);
    }

    /// Try to place a request on a replica right now.
    fn try_route(&mut self, tr: &TimedRequest) -> TryRoute {
        let plen = tr.req.prompt.len();
        let mnew = tr.req.max_new_tokens;
        // Least-outstanding (and affinity's least-outstanding spill path)
        // read the warmth credit; round-robin discards it, so skip the
        // per-replica radix walk there.
        let want_warmth = !matches!(self.policy, RoutePolicy::RoundRobin);
        let mut views: Vec<ReplicaView> = Vec::new();
        let mut healthy = 0usize;
        let mut too_long = 0usize;
        let mut oom = 0usize;
        for e in self.registry.entries() {
            if e.state != ReplicaState::Healthy {
                continue;
            }
            healthy += 1;
            match e.handle.could_ever_admit(&tr.req.prompt, mnew) {
                Admission::PromptTooLong => {
                    too_long += 1;
                    continue;
                }
                Admission::KvWouldOom => {
                    oom += 1;
                    continue;
                }
                _ => {}
            }
            views.push(ReplicaView {
                id: e.id,
                outstanding_tokens: e.handle.outstanding_tokens(),
                cached_prefix_tokens: if want_warmth {
                    e.handle.cached_prefix_tokens(&tr.req.prompt)
                } else {
                    0
                },
                admissible: e.handle.can_admit_now(&tr.req.prompt, mnew) == Admission::Accept,
            });
        }
        if healthy == 0 {
            return TryRoute::Reject(RejectReason::NoReplicas);
        }
        if views.is_empty() {
            // No healthy replica could serve this request even when idle.
            return TryRoute::Reject(if too_long >= oom {
                RejectReason::PromptTooLong { prompt_len: plen }
            } else {
                RejectReason::KvExhausted {
                    needed_tokens: plen + mnew,
                }
            });
        }
        let n = self.registry.len();
        match self
            .policy
            .pick(&mut self.policy_state, &views, n, &tr.req)
        {
            Some(id) => {
                if self
                    .registry
                    .handle_mut(id)
                    .submit(tr.req.clone(), tr.arrival_s)
                {
                    self.registry.count_dispatch(id);
                    TryRoute::Dispatched(id)
                } else {
                    TryRoute::NotNow
                }
            }
            None => TryRoute::NotNow,
        }
    }

    /// Admit an arriving request: dispatch, backlog, or reject. A
    /// non-empty backlog means older requests are still waiting, so new
    /// arrivals join it behind them rather than overtaking (FIFO fairness;
    /// an infeasible request is rejected when it reaches the head).
    pub fn admit(&mut self, tr: TimedRequest) {
        if !self.queue.is_empty() {
            self.backlog_or_reject(tr);
            return;
        }
        match self.try_route(&tr) {
            TryRoute::Dispatched(_) => {}
            TryRoute::Reject(reason) => self.rejected.push(RejectedRequest {
                id: tr.req.id,
                reason,
            }),
            TryRoute::NotNow => self.backlog_or_reject(tr),
        }
    }

    /// Move backlogged requests onto replicas, FIFO, stopping at the first
    /// that still cannot be placed (no overtaking).
    fn drain_backlog(&mut self) {
        while let Some(tr) = self.queue.pop() {
            match self.try_route(&tr) {
                TryRoute::Dispatched(_) => {}
                TryRoute::Reject(reason) => {
                    self.rejected.push(RejectedRequest {
                        id: tr.req.id,
                        reason,
                    });
                }
                TryRoute::NotNow => {
                    self.queue.push_front(tr);
                    break;
                }
            }
        }
    }

    /// Drive an open-loop workload (requests stamped with arrival times) to
    /// completion as a discrete-event simulation: always step the busy
    /// replica with the earliest clock; deliver arrivals in timestamp
    /// order; re-route the backlog whenever capacity frees.
    pub fn run_open_loop(&mut self, arrivals: Vec<TimedRequest>) -> Result<FleetRunReport> {
        let mut arrivals: std::collections::VecDeque<TimedRequest> = {
            let mut v = arrivals;
            v.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
            v.into()
        };
        let mut outputs: Vec<RequestOutput> = Vec::new();
        loop {
            // Deliver every arrival due at or before the next fleet event.
            if let Some((_, frontier)) = self.registry.min_busy_clock() {
                while arrivals.front().is_some_and(|a| a.arrival_s <= frontier) {
                    // lint:allow(no-unwrap-in-lib): is_some_and on front() just held in the loop condition
                    let tr = arrivals.pop_front().expect("front was checked");
                    self.admit(tr);
                }
            }
            self.drain_backlog();
            // Step the earliest busy replica (admissions above may have
            // created an earlier one).
            if let Some((id, _)) = self.registry.min_busy_clock() {
                let done = {
                    let h = self.registry.handle_mut(id);
                    h.step()?;
                    h.take_finished()
                };
                outputs.extend(done);
                continue;
            }
            // Whole fleet idle: jump to the next arrival, if any.
            if let Some(tr) = arrivals.pop_front() {
                self.registry.advance_idle_clocks(tr.arrival_s);
                self.admit(tr);
                continue;
            }
            // Idle, no arrivals left. Anything still backlogged faces the
            // fleet at maximum free capacity: place it or reject it.
            if !self.queue.is_empty() {
                for tr in self.queue.drain_all() {
                    match self.try_route(&tr) {
                        TryRoute::Dispatched(_) => {}
                        TryRoute::Reject(reason) => self.rejected.push(RejectedRequest {
                            id: tr.req.id,
                            reason,
                        }),
                        TryRoute::NotNow => self.rejected.push(RejectedRequest {
                            id: tr.req.id,
                            reason: RejectReason::Unroutable,
                        }),
                    }
                }
                continue;
            }
            break;
        }
        let metrics = FleetMetrics::collect(&self.registry, &self.rejected, self.queue.peak());
        Ok(FleetRunReport {
            outputs,
            rejected: std::mem::take(&mut self.rejected),
            metrics,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic fake replica: every queued request costs
    /// `step_cost_s` of virtual time and finishes in one step.
    struct MockReplica {
        label: String,
        clock: f64,
        queue: Vec<(Request, f64)>,
        step_cost_s: f64,
        max_tokens: usize,
        queue_cap: usize,
        finished: Vec<RequestOutput>,
        metrics: ServeMetrics,
    }

    impl MockReplica {
        fn new(label: &str, step_cost_s: f64) -> Self {
            Self {
                label: label.to_string(),
                clock: 0.0,
                queue: Vec::new(),
                step_cost_s,
                max_tokens: 1_000_000,
                queue_cap: 1_000_000,
                finished: Vec::new(),
                metrics: ServeMetrics::new(),
            }
        }
    }

    impl ReplicaHandle for MockReplica {
        fn label(&self) -> String {
            self.label.clone()
        }
        fn clock_s(&self) -> f64 {
            self.clock
        }
        fn advance_clock_to(&mut self, t_s: f64) {
            if self.queue.is_empty() {
                self.clock = self.clock.max(t_s);
            }
        }
        fn queued(&self) -> usize {
            self.queue.len()
        }
        fn active(&self) -> usize {
            0
        }
        fn outstanding_tokens(&self) -> usize {
            self.queue
                .iter()
                .map(|(r, _)| r.prompt.len() + r.max_new_tokens)
                .sum()
        }
        fn queue_capacity(&self) -> usize {
            self.queue_cap
        }
        fn could_ever_admit(&self, prompt: &[i32], max_new: usize) -> Admission {
            if prompt.len() + max_new > self.max_tokens {
                return Admission::KvWouldOom;
            }
            Admission::Accept
        }
        fn submit(&mut self, req: Request, arrival_s: f64) -> bool {
            if self.queue.len() >= self.queue_cap {
                return false;
            }
            self.queue.push((req, arrival_s));
            true
        }
        fn step(&mut self) -> Result<bool> {
            if self.queue.is_empty() {
                return Ok(false);
            }
            let (req, arrival_s) = self.queue.remove(0);
            self.clock = self.clock.max(arrival_s) + self.step_cost_s;
            let ttft = self.clock - arrival_s;
            self.metrics.ttft.record(ttft);
            self.metrics.generated_tokens += req.max_new_tokens as u64;
            self.metrics.requests_completed += 1;
            self.finished.push(RequestOutput {
                id: req.id,
                prompt_len: req.prompt.len(),
                tokens: vec![0; req.max_new_tokens],
                ttft_s: ttft,
                tpot_s: 0.0,
                total_s: ttft,
            });
            Ok(true)
        }
        fn take_finished(&mut self) -> Vec<RequestOutput> {
            std::mem::take(&mut self.finished)
        }
        fn evict_queued(&mut self) -> Vec<Request> {
            self.queue.drain(..).map(|(r, _)| r).collect()
        }
        fn abort_active(&mut self) -> Vec<RequestId> {
            Vec::new()
        }
        fn metrics(&self) -> &ServeMetrics {
            &self.metrics
        }
    }

    fn fleet(n: usize, policy: RoutePolicy) -> FleetRouter {
        let mut r = FleetRouter::new(FleetConfig {
            policy,
            queue_capacity: 1024,
        });
        for i in 0..n {
            r.add_replica(Box::new(MockReplica::new(&format!("mock{i}"), 0.1)));
        }
        r
    }

    fn burst(n: u64) -> Vec<TimedRequest> {
        (0..n)
            .map(|i| TimedRequest::new(Request::new(i, vec![1; 8], 4), 0.0))
            .collect()
    }

    #[test]
    fn round_robin_spreads_evenly() {
        let mut r = fleet(4, RoutePolicy::RoundRobin);
        let report = r.run_open_loop(burst(16)).unwrap();
        assert_eq!(report.outputs.len(), 16);
        assert!(report.rejected.is_empty());
        for rep in &report.metrics.replicas {
            assert_eq!(rep.dispatched, 4, "uneven spread: {:?}", report.metrics.replicas);
        }
    }

    #[test]
    fn empty_fleet_rejects_everything() {
        let mut r = fleet(0, RoutePolicy::RoundRobin);
        let report = r.run_open_loop(burst(3)).unwrap();
        assert!(report.outputs.is_empty());
        assert_eq!(report.rejected.len(), 3);
        assert!(report
            .rejected
            .iter()
            .all(|x| x.reason == RejectReason::NoReplicas));
    }

    #[test]
    fn kv_exhausted_rejected_with_reason() {
        let mut r = FleetRouter::new(FleetConfig::default());
        let mut m = MockReplica::new("small", 0.1);
        m.max_tokens = 15; // burst requests need 8+4=12; the big one 20+4=24
        r.add_replica(Box::new(m));
        let mut arrivals = burst(2);
        arrivals.push(TimedRequest::new(Request::new(99, vec![1; 20], 4), 0.0));
        let report = r.run_open_loop(arrivals).unwrap();
        assert_eq!(report.outputs.len(), 2);
        assert_eq!(report.rejected.len(), 1);
        assert_eq!(
            report.rejected[0].reason,
            RejectReason::KvExhausted { needed_tokens: 24 }
        );
    }

    #[test]
    fn drained_replica_gets_no_new_work_but_finishes() {
        let mut r = fleet(2, RoutePolicy::RoundRobin);
        // Seed replica 0 with work, then drain it.
        r.admit(TimedRequest::new(Request::new(100, vec![1; 8], 4), 0.0));
        assert_eq!(r.registry.dispatched(0), 1);
        r.drain_replica(0);
        let report = r.run_open_loop(burst(6)).unwrap();
        // All 6 new requests went to replica 1; replica 0 finished its one.
        assert_eq!(report.outputs.len(), 7);
        assert_eq!(r.registry.dispatched(0), 1);
        assert_eq!(r.registry.dispatched(1), 6);
        assert_eq!(r.registry.state(0), ReplicaState::Draining);
    }

    #[test]
    fn down_replica_backlog_is_rerouted() {
        let mut r = fleet(2, RoutePolicy::RoundRobin);
        r.admit(TimedRequest::new(Request::new(0, vec![1; 8], 4), 0.0));
        r.admit(TimedRequest::new(Request::new(1, vec![1; 8], 4), 0.0));
        // Both replicas hold one queued request; replica 0 dies.
        r.set_replica_state(0, ReplicaState::Down);
        let report = r.run_open_loop(Vec::new()).unwrap();
        assert_eq!(report.outputs.len(), 2, "request 0 must fail over");
        assert!(report.rejected.is_empty());
        assert_eq!(r.registry.dispatched(1), 2);
    }

    #[test]
    fn arrivals_respect_timestamps() {
        let mut r = fleet(1, RoutePolicy::LeastOutstandingTokens);
        let arrivals = vec![
            TimedRequest::new(Request::new(0, vec![1; 8], 4), 5.0),
            TimedRequest::new(Request::new(1, vec![1; 8], 4), 0.0),
        ];
        let report = r.run_open_loop(arrivals).unwrap();
        assert_eq!(report.outputs.len(), 2);
        // Request 1 (t=0) is served first; the fleet clock reaches at least
        // 5.0 + one step for request 0.
        assert!(report.metrics.makespan_s >= 5.0 + 0.1 - 1e-9);
        let o0 = report.outputs.iter().find(|o| o.id == 0).unwrap();
        assert!(o0.ttft_s <= 0.1 + 1e-9, "no phantom queueing: {}", o0.ttft_s);
    }

    #[test]
    fn backlog_drains_with_backpressure() {
        let mut r = FleetRouter::new(FleetConfig {
            policy: RoutePolicy::LeastOutstandingTokens,
            queue_capacity: 4,
        });
        let mut m = MockReplica::new("tight", 0.1);
        m.queue_cap = 1;
        r.add_replica(Box::new(m));
        // 8 simultaneous arrivals: 1 dispatches, 4 backlog, 3 rejected.
        let report = r.run_open_loop(burst(8)).unwrap();
        assert_eq!(report.outputs.len(), 5);
        assert_eq!(report.rejected.len(), 3);
        assert!(report
            .rejected
            .iter()
            .all(|x| matches!(x.reason, RejectReason::QueueFull { capacity: 4 })));
        assert_eq!(report.metrics.queued_peak, 4);
    }
}
