//! Replica registry: the fleet's member list with health/drain state and
//! per-replica dispatch accounting.
//!
//! States follow the usual load-balancer lifecycle:
//!
//! * `Healthy`  — receives new work.
//! * `Draining` — no new work, but keeps stepping until its queued and
//!   active requests complete (graceful removal / rolling restart).
//! * `Down`     — stepped never; its queued backlog is evicted and
//!   re-routed by the router.

use super::ReplicaHandle;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicaState {
    Healthy,
    Draining,
    Down,
}

pub struct ReplicaEntry {
    pub id: usize,
    pub state: ReplicaState,
    /// Requests this replica was handed by the router.
    pub dispatched: u64,
    pub handle: Box<dyn ReplicaHandle>,
}

#[derive(Default)]
pub struct ReplicaRegistry {
    entries: Vec<ReplicaEntry>,
}

impl ReplicaRegistry {
    pub fn new() -> Self {
        Self { entries: Vec::new() }
    }

    pub fn register(&mut self, handle: Box<dyn ReplicaHandle>) -> usize {
        let id = self.entries.len();
        self.entries.push(ReplicaEntry {
            id,
            state: ReplicaState::Healthy,
            dispatched: 0,
            handle,
        });
        id
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn entries(&self) -> &[ReplicaEntry] {
        &self.entries
    }

    pub fn state(&self, id: usize) -> ReplicaState {
        self.entries[id].state
    }

    pub fn set_state(&mut self, id: usize, state: ReplicaState) {
        self.entries[id].state = state;
    }

    pub fn handle(&self, id: usize) -> &dyn ReplicaHandle {
        &*self.entries[id].handle
    }

    pub fn handle_mut(&mut self, id: usize) -> &mut dyn ReplicaHandle {
        &mut *self.entries[id].handle
    }

    pub fn count_dispatch(&mut self, id: usize) {
        self.entries[id].dispatched += 1;
    }

    pub fn dispatched(&self, id: usize) -> u64 {
        self.entries[id].dispatched
    }

    /// The not-Down replica with work and the smallest clock — the fleet's
    /// next discrete event.
    pub fn min_busy_clock(&self) -> Option<(usize, f64)> {
        self.entries
            .iter()
            .filter(|e| e.state != ReplicaState::Down && e.handle.has_work())
            .map(|e| (e.id, e.handle.clock_s()))
            .min_by(|a, b| a.1.total_cmp(&b.1))
    }

    /// Jump every idle (workless, not-Down) replica's clock to `t_s`, so a
    /// quiet fleet doesn't "serve" requests before they arrive.
    pub fn advance_idle_clocks(&mut self, t_s: f64) {
        for e in &mut self.entries {
            if e.state != ReplicaState::Down && !e.handle.has_work() {
                e.handle.advance_clock_to(t_s);
            }
        }
    }
}
