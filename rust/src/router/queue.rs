//! Fleet-level admission: the bounded backlog queue in front of every
//! replica, and the typed outcomes of an admission attempt.
//!
//! The router dispatches a request straight to a replica when one can take
//! it; otherwise the request waits here. When the queue is full — or no
//! replica could *ever* serve the request (prompt exceeds every compiled
//! bucket, or its KV footprint exceeds every replica's whole cache) — the
//! request is rejected with a reason instead of being silently dropped.

use std::collections::VecDeque;

use crate::coordinator::Request;

/// A request stamped with its arrival time on the fleet clock (seconds
/// since the fleet epoch; virtual for simulated replicas).
#[derive(Clone, Debug)]
pub struct TimedRequest {
    pub req: Request,
    pub arrival_s: f64,
}

impl TimedRequest {
    pub fn new(req: Request, arrival_s: f64) -> Self {
        Self { req, arrival_s }
    }
}

/// Result of checking one replica's ability to take a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    Accept,
    /// The replica's own admission queue is at capacity.
    QueueFull,
    /// The request's KV footprint (prompt + generation budget) exceeds the
    /// replica's total cache — it would OOM even on an idle replica.
    KvWouldOom,
    /// The prompt exceeds every compiled prefill bucket.
    PromptTooLong,
}

/// Why the fleet refused a request (returned to the client, with detail).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// Backpressure: the fleet backlog queue is at capacity.
    QueueFull { capacity: usize },
    /// Every replica's KV admission would OOM on this request.
    KvExhausted { needed_tokens: usize },
    /// The prompt exceeds every replica's compiled prefill buckets.
    PromptTooLong { prompt_len: usize },
    /// No healthy replica is registered.
    NoReplicas,
    /// The fleet went idle with this request still unplaceable (e.g. every
    /// replica's local queue capacity is zero).
    Unroutable,
    /// The request was in flight on a replica that went down; its KV
    /// history died with the replica, so it cannot be transparently
    /// migrated.
    ReplicaFailed { replica: usize },
}

impl RejectReason {
    /// Every label [`RejectReason::label`] can produce, in a fixed order —
    /// the source of truth for zero-filled Prometheus counter families, so
    /// a new variant cannot ship without a corresponding family (the
    /// exhaustiveness test pins this list against the enum).
    pub const ALL_LABELS: [&'static str; 6] = [
        "queue_full",
        "kv_exhausted",
        "prompt_too_long",
        "no_replicas",
        "unroutable",
        "replica_failed",
    ];

    pub fn label(&self) -> &'static str {
        match self {
            RejectReason::QueueFull { .. } => "queue_full",
            RejectReason::KvExhausted { .. } => "kv_exhausted",
            RejectReason::PromptTooLong { .. } => "prompt_too_long",
            RejectReason::NoReplicas => "no_replicas",
            RejectReason::Unroutable => "unroutable",
            RejectReason::ReplicaFailed { .. } => "replica_failed",
        }
    }
}

/// Bounded FIFO backlog for requests no replica can take right now.
#[derive(Debug)]
pub struct FleetQueue {
    q: VecDeque<TimedRequest>,
    capacity: usize,
    peak: usize,
}

impl FleetQueue {
    pub fn new(capacity: usize) -> Self {
        Self {
            q: VecDeque::new(),
            capacity,
            peak: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Enqueue; bounces the request back when full.
    pub fn push(&mut self, tr: TimedRequest) -> Option<TimedRequest> {
        if self.q.len() >= self.capacity {
            return Some(tr);
        }
        self.q.push_back(tr);
        self.peak = self.peak.max(self.q.len());
        None
    }

    pub fn front(&self) -> Option<&TimedRequest> {
        self.q.front()
    }

    pub fn pop(&mut self) -> Option<TimedRequest> {
        self.q.pop_front()
    }

    /// Return a popped-but-unplaced request to the head.
    ///
    /// The request held a slot when it was popped, but new pushes may have
    /// refilled the queue since — so the capacity invariant is re-checked
    /// (debug builds assert it; callers must re-queue before accepting new
    /// pushes) and `peak` is updated like every other enqueue. Skipping
    /// both here let the backlog silently exceed `capacity` and made the
    /// saturation signal undercount exactly when the overload benches read
    /// it. The request is never dropped: it was already admitted, and
    /// losing it would violate the zero-lost-requests contract.
    pub fn push_front(&mut self, tr: TimedRequest) {
        debug_assert!(
            self.q.len() < self.capacity,
            "push_front would exceed capacity {} — re-queue before accepting new pushes",
            self.capacity
        );
        self.q.push_front(tr);
        self.peak = self.peak.max(self.q.len());
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Deepest the backlog ever got (a saturation signal for reports).
    pub fn peak(&self) -> usize {
        self.peak
    }

    pub fn drain_all(&mut self) -> Vec<TimedRequest> {
        self.q.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tr(id: u64) -> TimedRequest {
        TimedRequest::new(Request::new(id, vec![1, 2], 4), id as f64)
    }

    #[test]
    fn fifo_with_bounce_and_peak() {
        let mut q = FleetQueue::new(2);
        assert!(q.push(tr(0)).is_none());
        assert!(q.push(tr(1)).is_none());
        let bounced = q.push(tr(2));
        assert_eq!(bounced.unwrap().req.id, 2);
        assert_eq!(q.len(), 2);
        assert_eq!(q.peak(), 2);
        assert_eq!(q.pop().unwrap().req.id, 0);
        assert_eq!(q.front().unwrap().req.id, 1);
        let rest = q.drain_all();
        assert_eq!(rest.len(), 1);
        assert!(q.is_empty());
        assert_eq!(q.peak(), 2, "peak survives draining");
    }

    #[test]
    fn push_front_after_pop_and_push_keeps_peak_and_capacity_honest() {
        // The pop → push → push_front interleaving that used to corrupt
        // the accounting: a popped request is returned to the head after a
        // new arrival took its slot's worth of headroom.
        let mut q = FleetQueue::new(4);
        assert!(q.push(tr(0)).is_none());
        assert!(q.push(tr(1)).is_none());
        assert_eq!(q.peak(), 2);
        let popped = q.pop().unwrap();
        assert_eq!(popped.req.id, 0);
        assert!(q.push(tr(2)).is_none());
        assert!(q.push(tr(3)).is_none()); // len back to 3
        q.push_front(popped);
        // FIFO order restored with the returned request at the head …
        assert_eq!(q.front().unwrap().req.id, 0);
        assert_eq!(q.len(), 4);
        // … and the saturation signal saw the true depth (the old
        // push_front left peak at 3).
        assert_eq!(q.peak(), 4, "push_front must update peak");
        assert!(q.len() <= q.capacity(), "capacity invariant");
        assert_eq!(q.push(tr(4)).map(|t| t.req.id), Some(4), "full queue bounces");
    }

    #[test]
    fn reject_reason_labels() {
        assert_eq!(RejectReason::QueueFull { capacity: 8 }.label(), "queue_full");
        assert_eq!(RejectReason::KvExhausted { needed_tokens: 9 }.label(), "kv_exhausted");
        assert_eq!(RejectReason::PromptTooLong { prompt_len: 4 }.label(), "prompt_too_long");
        assert_eq!(RejectReason::NoReplicas.label(), "no_replicas");
        assert_eq!(RejectReason::Unroutable.label(), "unroutable");
        assert_eq!(RejectReason::ReplicaFailed { replica: 3 }.label(), "replica_failed");
    }

    #[test]
    fn every_label_is_declared_exactly_once() {
        // ALL_LABELS drives the zero-filled Prometheus reject families;
        // every constructible variant's label must appear in it exactly
        // once (a new variant that misses this list fails here).
        let variants = [
            RejectReason::QueueFull { capacity: 1 },
            RejectReason::KvExhausted { needed_tokens: 1 },
            RejectReason::PromptTooLong { prompt_len: 1 },
            RejectReason::NoReplicas,
            RejectReason::Unroutable,
            RejectReason::ReplicaFailed { replica: 0 },
        ];
        assert_eq!(variants.len(), RejectReason::ALL_LABELS.len());
        for v in &variants {
            assert_eq!(
                RejectReason::ALL_LABELS.iter().filter(|l| **l == v.label()).count(),
                1,
                "label {:?} must appear exactly once in ALL_LABELS",
                v.label()
            );
        }
    }
}
