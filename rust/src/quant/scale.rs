//! Max-abs scaling methods (paper §3.2.1–§3.2.4) and pow2 rounding (Eq. 14).

use crate::fp8::Fp8Format;

/// Activation scaling policy (paper Fig. 1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ActScaling {
    /// Scale factor fixed at 1 regardless of statistics (the paper's
    /// "Unit scale" baseline in Tables 2–4).
    Unit,
    /// Static per-tensor scaling from calibration stats (Eq. 15).
    PerTensorStatic { backoff: f32 },
    /// Dynamic (JiT) per-tensor scaling from the current batch (Eq. 9a).
    PerTensorDynamic { backoff: f32 },
    /// Dynamic per-sample (per-token) scaling (Eq. 17; static per-sample is
    /// impossible — §2.3.1 / Fig. 1 caption).
    PerSampleDynamic { backoff: f32 },
}

/// Weight scaling policy (paper Fig. 2). Weights are always quantized
/// offline (§2.1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WeightScaling {
    Unit,
    /// Per-tensor from max-abs stats (Eq. 18).
    PerTensor,
    /// Per-output-channel from max-abs stats (Eq. 20).
    PerChannel,
    /// MSE-minimizing per-tensor search (Eq. 22) over a scale set.
    MsePerTensor(super::search::ScaleSet),
    /// MSE-minimizing per-output-channel search (Eq. 24) over a scale set.
    MsePerChannel(super::search::ScaleSet),
}

/// Eq. 15a: `s_x = r_x / (β·r_q)`.
pub fn act_scale_per_tensor(r_x: f32, backoff: f32, format: Fp8Format) -> f32 {
    sanitize(r_x / (backoff * format.r_q()))
}

/// Eq. 17a: `s_x[i] = r_x-[i] / (β·r_q)` for each sample i.
pub fn act_scale_per_sample(r_x_rows: &[f32], backoff: f32, format: Fp8Format) -> Vec<f32> {
    r_x_rows
        .iter()
        .map(|r| sanitize(r / (backoff * format.r_q())))
        .collect()
}

/// Eq. 18a: `s_w = r_w / r_q`.
pub fn weight_scale_per_tensor(r_w: f32, format: Fp8Format) -> f32 {
    sanitize(r_w / format.r_q())
}

/// Eq. 20a: `s_w[k] = r_w-[k] / r_q`.
pub fn weight_scale_per_channel(r_w_rows: &[f32], format: Fp8Format) -> Vec<f32> {
    r_w_rows
        .iter()
        .map(|r| sanitize(r / format.r_q()))
        .collect()
}

/// Eq. 14: round a scale up to the next power of two, `2^⌈log2 s⌉`.
/// (Rounding *up* guarantees the scaled max still fits in range.)
///
/// The exponent is clamped to the f32 normal range [-126, 127]: `powi` of
/// a large negative exponent computes via `1/2^|e|`, whose denominator
/// overflows to infinity for |e| > 128 and returns 0.0 — and a zero scale
/// poisons every downstream division. Tiny scales (< 2^-126) round up to
/// 2^-126 (still an upper bound); huge scales (≥ 2^127) clamp down to
/// 2^127, trading an upper-bound guarantee no f32 pow2 can provide for a
/// finite, positive result.
pub fn round_scale_pow2(s: f32) -> f32 {
    if s <= 0.0 || !s.is_finite() {
        return 1.0;
    }
    let e = s.log2().ceil().clamp(-126.0, 127.0) as i32;
    (2.0f32).powi(e)
}

/// Zero / non-finite statistics degrade to the identity scale: an all-zero
/// tensor quantizes exactly at any scale, and a poisoned statistic must not
/// poison the weights.
#[inline]
fn sanitize(s: f32) -> f32 {
    if s > 0.0 && s.is_finite() {
        s
    } else {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::{decode, encode_rne, CastMode};

    #[test]
    fn per_tensor_scale_maps_max_to_rq() {
        let f = Fp8Format::E4M3; // r_q = 448
        let s = act_scale_per_tensor(896.0, 1.0, f);
        assert_eq!(s, 2.0);
        // The scaled max hits exactly r_q → encodes to max code, no clipping.
        let code = encode_rne(896.0 / s, f, CastMode::SatFinite);
        assert_eq!(decode(code, f), 448.0);
    }

    #[test]
    fn backoff_leaves_headroom() {
        let f = Fp8Format::E4M3Gaudi2; // r_q = 240
        let s_nb = act_scale_per_tensor(240.0, 1.0, f);
        let s_b = act_scale_per_tensor(240.0, 0.5, f);
        assert_eq!(s_nb, 1.0);
        assert_eq!(s_b, 2.0); // scaled max = 120 → 2× headroom
        assert!(s_b > s_nb);
    }

    #[test]
    fn per_sample_scales_one_per_row() {
        let f = Fp8Format::E4M3;
        let rows = [448.0f32, 224.0, 0.0];
        let s = act_scale_per_sample(&rows, 1.0, f);
        assert_eq!(s, vec![1.0, 0.5, 1.0]); // zero row degrades to identity
    }

    #[test]
    fn weight_scales() {
        let f = Fp8Format::E4M3Gaudi2;
        assert_eq!(weight_scale_per_tensor(480.0, f), 2.0);
        assert_eq!(
            weight_scale_per_channel(&[240.0, 120.0, 960.0], f),
            vec![1.0, 0.5, 4.0]
        );
    }

    #[test]
    fn pow2_rounding_rounds_up() {
        assert_eq!(round_scale_pow2(1.0), 1.0);
        assert_eq!(round_scale_pow2(1.01), 2.0);
        assert_eq!(round_scale_pow2(0.9), 1.0);
        assert_eq!(round_scale_pow2(0.5), 0.5);
        assert_eq!(round_scale_pow2(3.0), 4.0);
        assert_eq!(round_scale_pow2(0.0), 1.0);
        assert_eq!(round_scale_pow2(f32::NAN), 1.0);
    }

    #[test]
    fn pow2_rounding_survives_subnormal_scales() {
        // Regression: powi(large negative exponent) underflows to 0.0 via
        // its 1/2^|e| reciprocal; the result must stay positive and finite
        // and remain an upper bound in the clamp range.
        for s in [1e-40f32, 1e-44, f32::MIN_POSITIVE, 2.0f32.powi(-140)] {
            let p = round_scale_pow2(s);
            assert!(p > 0.0 && p.is_finite(), "s={s:e} -> {p:e}");
            assert!(p >= s, "s={s:e} -> {p:e} not an upper bound");
        }
        // Huge scales clamp to the largest f32 pow2 instead of inf.
        for s in [1e38f32, f32::MAX] {
            let p = round_scale_pow2(s);
            assert!(p > 0.0 && p.is_finite(), "s={s:e} -> {p:e}");
            assert_eq!(p, 2.0f32.powi(127));
        }
        // In-range behavior unchanged.
        assert_eq!(round_scale_pow2(2.0f32.powi(-100)), 2.0f32.powi(-100));
    }

    #[test]
    fn pow2_rounding_never_causes_clipping() {
        // s_pow2 ≥ s, so max/s_pow2 ≤ r_q always.
        let f = Fp8Format::E4M3;
        let mut rng = crate::util::rng::XorShiftRng::new(77);
        for _ in 0..1000 {
            let r_x = rng.range_f32(1e-3, 1e4);
            let s = act_scale_per_tensor(r_x, 1.0, f);
            let sp = round_scale_pow2(s);
            assert!(sp >= s * 0.9999);
            assert!(r_x / sp <= f.r_q() * 1.0001, "r_x={r_x} sp={sp}");
        }
    }

    #[test]
    fn sanitize_handles_degenerate_stats() {
        let f = Fp8Format::E4M3;
        assert_eq!(act_scale_per_tensor(0.0, 1.0, f), 1.0);
        assert_eq!(act_scale_per_tensor(f32::INFINITY, 1.0, f), 1.0);
        assert_eq!(weight_scale_per_tensor(f32::NAN, f), 1.0);
    }
}
