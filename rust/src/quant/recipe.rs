//! Quantization schemes and the deployable quantized linear layer.
//!
//! A [`QuantScheme`] names one cell of the paper's evaluation grid
//! (Tables 2–4: Unit Scale / Per Tensor Scaling / Per Channel Scaling, plus
//! the §3.2 variants). [`QuantizedLinear::prepare`] turns a high-precision
//! weight + calibration statistics into a deployable layer; `forward`
//! executes Eq. 2 with online activation quantization.

use crate::calib::ActStats;
use crate::fp8::Fp8Format;
use crate::gemm::{quantize_matrix, scaled_gemm, DiagScale, QMatrix, QuantRounding};
use crate::quant::kv::KvDtype;
use crate::quant::scale::{
    act_scale_per_sample, act_scale_per_tensor, round_scale_pow2, weight_scale_per_channel,
    weight_scale_per_tensor, ActScaling, WeightScaling,
};
use crate::quant::search::{mse_scale_per_channel, mse_scale_per_tensor};
use crate::quant::smoothquant::smoothquant_scales;
use crate::tensor::Tensor2;

/// Cast rounding (paper §2.4: RNE default; stochastic available).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Rounding {
    Nearest,
    Stochastic { seed: u64 },
}

impl Rounding {
    fn to_gemm(self) -> QuantRounding {
        match self {
            Rounding::Nearest => QuantRounding::Nearest,
            Rounding::Stochastic { seed } => QuantRounding::Stochastic { seed },
        }
    }
}

/// SmoothQuant configuration (§3.2.7).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SmoothQuantCfg {
    pub alpha: f32,
    pub pow2: bool,
}

/// A complete quantization scheme for one linear layer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantScheme {
    pub format: Fp8Format,
    pub act: ActScaling,
    pub weight: WeightScaling,
    /// When set, derive `s_c` via SmoothQuant and fold it into both sides.
    pub smoothquant: Option<SmoothQuantCfg>,
    /// Round all scales to powers of two (Eq. 14) — required for the
    /// hardware-accelerated path.
    pub pow2_scales: bool,
    pub rounding: Rounding,
    /// Round GEMM output to BF16 (hardware behaviour).
    pub bf16_out: bool,
    /// KV-cache storage dtype the recipe deploys with. The engine's
    /// `KvStore` and the capacity model read this; the Eq. 2 linears are
    /// unaffected. Defaults to FP8 in the scheme's format — the paper's
    /// serving configuration (§4.2.4: 70B fits one Gaudi 2 only this way).
    pub kv_dtype: KvDtype,
}

impl QuantScheme {
    /// The paper's Tables 2–4 configurations.
    pub fn unit_scale(format: Fp8Format) -> Self {
        Self {
            format,
            act: ActScaling::Unit,
            weight: WeightScaling::Unit,
            smoothquant: None,
            pow2_scales: false,
            rounding: Rounding::Nearest,
            bf16_out: true,
            kv_dtype: KvDtype::Fp8(format),
        }
    }

    pub fn per_tensor(format: Fp8Format) -> Self {
        Self {
            format,
            act: ActScaling::PerTensorStatic { backoff: 1.0 },
            weight: WeightScaling::PerTensor,
            smoothquant: None,
            pow2_scales: false,
            rounding: Rounding::Nearest,
            bf16_out: true,
            kv_dtype: KvDtype::Fp8(format),
        }
    }

    pub fn per_channel(format: Fp8Format) -> Self {
        Self {
            weight: WeightScaling::PerChannel,
            ..Self::per_tensor(format)
        }
    }

    /// Hardware-accelerated variant: per-tensor + pow2 scales.
    pub fn per_tensor_hw(format: Fp8Format) -> Self {
        Self {
            pow2_scales: true,
            ..Self::per_tensor(format)
        }
    }

    pub fn smoothquant(format: Fp8Format, alpha: f32) -> Self {
        Self {
            smoothquant: Some(SmoothQuantCfg { alpha, pow2: false }),
            ..Self::per_channel(format)
        }
    }

    /// Same scheme, different KV-cache storage dtype.
    pub fn with_kv_dtype(mut self, kv_dtype: KvDtype) -> Self {
        self.kv_dtype = kv_dtype;
        self
    }

    pub fn label(&self) -> String {
        if self.smoothquant.is_some() {
            return "SmoothQuant".into();
        }
        match (self.act, self.weight) {
            (ActScaling::Unit, WeightScaling::Unit) => "Unit Scale".into(),
            (_, WeightScaling::PerTensor) if self.pow2_scales => "Per Tensor (HW pow2)".into(),
            (_, WeightScaling::PerTensor) => "Per Tensor Scaling".into(),
            (_, WeightScaling::PerChannel) => "Per Channel Scaling".into(),
            (_, WeightScaling::MsePerTensor(_)) => "MSE Per Tensor".into(),
            (_, WeightScaling::MsePerChannel(_)) => "MSE Per Channel".into(),
            _ => format!("{:?}/{:?}", self.act, self.weight),
        }
    }
}

/// A linear layer quantized offline, ready for inference.
#[derive(Clone, Debug)]
pub struct QuantizedLinear {
    pub scheme: QuantScheme,
    /// Quantized weights `Q(S_c·Wᵀ·S_w⁻¹)` stored as C'×C codes.
    pub wq: QMatrix,
    /// Weight descale `s_w` (scalar or per-output-channel).
    pub s_w: DiagScale,
    /// SmoothQuant common-dim scales `s_c` (empty = unit).
    pub s_c: Vec<f32>,
    /// Static activation scale from calibration (None → dynamic or unit).
    pub s_x_static: Option<f32>,
}

impl QuantizedLinear {
    /// Offline preparation: compute scales from calibration stats, quantize
    /// the weight (Eq. 3b / 4b).
    pub fn prepare(w: &Tensor2, stats: Option<&ActStats>, scheme: QuantScheme) -> Self {
        let fmt = scheme.format;
        let rounding = scheme.rounding.to_gemm();

        // SmoothQuant path computes s_c, s_x, s_w jointly.
        if let Some(sq) = scheme.smoothquant {
            // lint:allow(no-unwrap-in-lib): recipe validation rejects SmoothQuant schemes without calibration stats
            let stats = stats.expect("SmoothQuant requires calibration stats");
            let per_channel = matches!(
                scheme.weight,
                WeightScaling::PerChannel | WeightScaling::MsePerChannel(_)
            );
            let backoff = match scheme.act {
                ActScaling::PerTensorStatic { backoff } => backoff,
                _ => 1.0,
            };
            let r =
                smoothquant_scales(&stats.r_x_cols, w, sq.alpha, backoff, fmt, per_channel, sq.pow2);
            let mut s_w = r.s_w.clone();
            let mut s_x = r.s_x;
            if scheme.pow2_scales {
                for s in &mut s_w {
                    *s = round_scale_pow2(*s);
                }
                s_x = round_scale_pow2(s_x);
            }
            // Quantize: Q(S_c · Wᵀ · S_w⁻¹) — W is C'×C, so columns carry
            // s_c (multiply) and rows carry s_w (divide).
            let inv_c: Vec<f32> = r.s_c.iter().map(|s| 1.0 / s).collect();
            let wq = quantize_matrix(&w.scale_cols(&r.s_c), &s_w, &[], fmt, rounding);
            let _ = inv_c;
            return Self {
                scheme,
                wq,
                s_w: if s_w.len() == 1 {
                    DiagScale::Scalar(s_w[0])
                } else {
                    DiagScale::Vector(s_w)
                },
                s_c: r.s_c,
                s_x_static: Some(s_x),
            };
        }

        // Weight scales.
        let rows: Vec<&[f32]> = (0..w.rows).map(|r| w.row(r)).collect();
        let mut s_w_vec: Vec<f32> = match scheme.weight {
            WeightScaling::Unit => vec![1.0],
            WeightScaling::PerTensor => {
                vec![weight_scale_per_tensor(crate::tensor::abs_max(w), fmt)]
            }
            WeightScaling::PerChannel => {
                weight_scale_per_channel(&crate::tensor::row_abs_max(w), fmt)
            }
            WeightScaling::MsePerTensor(set) => vec![mse_scale_per_tensor(&rows, fmt, set)],
            WeightScaling::MsePerChannel(set) => mse_scale_per_channel(&rows, fmt, set),
        };
        if scheme.pow2_scales {
            for s in &mut s_w_vec {
                *s = round_scale_pow2(*s);
            }
        }
        let wq = quantize_matrix(w, &s_w_vec, &[], fmt, rounding);

        // Static activation scale (Eq. 15) if the scheme uses one.
        let s_x_static = match scheme.act {
            ActScaling::Unit => Some(1.0),
            ActScaling::PerTensorStatic { backoff } => {
                // lint:allow(no-unwrap-in-lib): recipe validation rejects static-act schemes without calibration stats
                let st = stats.expect("static activation scaling requires calibration stats");
                let mut s = act_scale_per_tensor(st.r_x, backoff, fmt);
                if scheme.pow2_scales {
                    s = round_scale_pow2(s);
                }
                Some(s)
            }
            ActScaling::PerTensorDynamic { .. } | ActScaling::PerSampleDynamic { .. } => None,
        };

        Self {
            scheme,
            wq,
            s_w: if s_w_vec.len() == 1 {
                DiagScale::Scalar(s_w_vec[0])
            } else {
                DiagScale::Vector(s_w_vec)
            },
            s_c: Vec::new(),
            s_x_static,
        }
    }

    /// Online inference: quantize activations (Eq. 3a / 4a), multiply,
    /// descale (Eq. 2).
    pub fn forward(&self, x: &Tensor2) -> Tensor2 {
        let fmt = self.scheme.format;
        let rounding = self.scheme.rounding.to_gemm();

        // Activation scales: static, dynamic per-tensor, or dynamic per-sample.
        let s_x: DiagScale = match self.scheme.act {
            ActScaling::Unit => DiagScale::Scalar(1.0),
            ActScaling::PerTensorStatic { .. } => {
                // lint:allow(no-unwrap-in-lib): s_x_static is populated at build time for every PerTensorStatic scheme
                DiagScale::Scalar(self.s_x_static.expect("static scale missing"))
            }
            ActScaling::PerTensorDynamic { backoff } => {
                let r = if self.s_c.is_empty() {
                    crate::tensor::abs_max(x)
                } else {
                    // Measure on the smoothed activation.
                    let inv: Vec<f32> = self.s_c.iter().map(|s| 1.0 / s).collect();
                    crate::tensor::abs_max(&x.scale_cols(&inv))
                };
                let mut s = act_scale_per_tensor(r, backoff, fmt);
                if self.scheme.pow2_scales {
                    s = round_scale_pow2(s);
                }
                DiagScale::Scalar(s)
            }
            ActScaling::PerSampleDynamic { backoff } => {
                let rows = if self.s_c.is_empty() {
                    crate::tensor::row_abs_max(x)
                } else {
                    let inv: Vec<f32> = self.s_c.iter().map(|s| 1.0 / s).collect();
                    crate::tensor::row_abs_max(&x.scale_cols(&inv))
                };
                let mut s = act_scale_per_sample(&rows, backoff, fmt);
                if self.scheme.pow2_scales {
                    for v in &mut s {
                        *v = round_scale_pow2(*v);
                    }
                }
                DiagScale::Vector(s)
            }
        };

        // Quantize activations: Q(S_x⁻¹ · X · S_c⁻¹).
        let s_x_rows = s_x.to_vec(if s_x.len_or_1() == 1 { 1 } else { x.rows });
        let xq = quantize_matrix(x, &s_x_rows, &self.s_c, fmt, rounding);

        scaled_gemm(&xq, &self.wq, &s_x, &self.s_w, self.scheme.bf16_out)
    }

    /// High-precision reference forward (Eq. 1).
    pub fn forward_reference(w: &Tensor2, x: &Tensor2) -> Tensor2 {
        crate::tensor::matmul_nt(x, w)
    }

    /// Relative Frobenius error of this layer vs the reference on input `x`.
    pub fn relative_error(&self, w: &Tensor2, x: &Tensor2) -> f64 {
        let q = self.forward(x);
        let r = Self::forward_reference(w, x);
        (q.sub(&r).fro_norm_sq() / r.fro_norm_sq().max(1e-30)).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::ActObserver;
    use crate::util::rng::XorShiftRng;

    fn make(n: usize, c: usize, k: usize, outliers: bool, seed: u64) -> (Tensor2, Tensor2, ActStats) {
        let mut rng = XorShiftRng::new(seed);
        let x = if outliers {
            // Outlier channels reaching |x| ~ 1000 ≫ r_q: clipped hard under
            // unit scaling — the Mistral/Mixtral structure (Table 4).
            Tensor2::randn_outlier_cols(n, c, 1.0, 0.06, 400.0, &mut rng)
        } else {
            Tensor2::randn(n, c, 1.0, &mut rng)
        };
        let w = Tensor2::randn(k, c, 0.05, &mut rng);
        let mut obs = ActObserver::new(c);
        obs.observe(&x);
        (x, w, obs.finalize())
    }

    #[test]
    fn scaled_schemes_beat_unit_scale() {
        // The Tables 2–4 headline: unit scale is consistently worst.
        let (x, w, stats) = make(64, 128, 32, false, 1);
        let f = Fp8Format::E4M3Gaudi2;
        let unit = QuantizedLinear::prepare(&w, None, QuantScheme::unit_scale(f));
        let pt = QuantizedLinear::prepare(&w, Some(&stats), QuantScheme::per_tensor(f));
        let pc = QuantizedLinear::prepare(&w, Some(&stats), QuantScheme::per_channel(f));
        let (eu, et, ec) = (
            unit.relative_error(&w, &x),
            pt.relative_error(&w, &x),
            pc.relative_error(&w, &x),
        );
        assert!(et < eu, "per-tensor {et} vs unit {eu}");
        assert!(ec < eu, "per-channel {ec} vs unit {eu}");
        // per-channel ≤ per-tensor (paper: "slight advantage").
        assert!(ec <= et * 1.05, "pc {ec} pt {et}");
    }

    #[test]
    fn unit_scale_catastrophic_on_outlier_activations() {
        // The Mistral failure mode (Table 4: unit scale +136% PPL).
        let (x, w, stats) = make(64, 128, 32, true, 2);
        let f = Fp8Format::E4M3Gaudi2;
        let unit = QuantizedLinear::prepare(&w, None, QuantScheme::unit_scale(f));
        let pt = QuantizedLinear::prepare(&w, Some(&stats), QuantScheme::per_tensor(f));
        let (eu, et) = (unit.relative_error(&w, &x), pt.relative_error(&w, &x));
        assert!(
            eu > 3.0 * et,
            "outliers should blow up unit scale: unit {eu} vs per-tensor {et}"
        );
    }

    #[test]
    fn smoothquant_helps_outlier_activations() {
        let (x, w, stats) = make(64, 128, 32, true, 3);
        let f = Fp8Format::E4M3Gaudi2;
        let pt = QuantizedLinear::prepare(&w, Some(&stats), QuantScheme::per_tensor(f));
        let sq = QuantizedLinear::prepare(&w, Some(&stats), QuantScheme::smoothquant(f, 0.5));
        let (et, es) = (pt.relative_error(&w, &x), sq.relative_error(&w, &x));
        assert!(es < et, "smoothquant {es} vs per-tensor {et}");
    }

    #[test]
    fn dynamic_per_sample_at_least_as_good_as_static() {
        let (x, w, stats) = make(64, 128, 32, false, 4);
        let f = Fp8Format::E4M3;
        let st = QuantizedLinear::prepare(&w, Some(&stats), QuantScheme::per_tensor(f));
        let dyn_scheme = QuantScheme {
            act: ActScaling::PerSampleDynamic { backoff: 1.0 },
            ..QuantScheme::per_tensor(f)
        };
        let dy = QuantizedLinear::prepare(&w, Some(&stats), dyn_scheme);
        let (es, ed) = (st.relative_error(&w, &x), dy.relative_error(&w, &x));
        assert!(ed <= es * 1.02, "dynamic {ed} vs static {es}");
    }

    #[test]
    fn hw_pow2_scheme_emits_pow2_scales() {
        let (_, w, stats) = make(8, 64, 16, false, 5);
        let f = Fp8Format::E4M3Gaudi2;
        let hw = QuantizedLinear::prepare(&w, Some(&stats), QuantScheme::per_tensor_hw(f));
        let s_x = hw.s_x_static.unwrap();
        assert_eq!(s_x.log2().fract(), 0.0);
        if let DiagScale::Scalar(s) = hw.s_w {
            assert_eq!(s.log2().fract(), 0.0);
        } else {
            panic!("expected scalar weight scale");
        }
    }

    #[test]
    fn pow2_costs_little_accuracy() {
        // HW pow2 rounding of scales degrades error by a bounded factor.
        let (x, w, stats) = make(64, 128, 32, false, 6);
        let f = Fp8Format::E4M3Gaudi2;
        let sw = QuantizedLinear::prepare(&w, Some(&stats), QuantScheme::per_tensor(f));
        let hw = QuantizedLinear::prepare(&w, Some(&stats), QuantScheme::per_tensor_hw(f));
        let (e_sw, e_hw) = (sw.relative_error(&w, &x), hw.relative_error(&w, &x));
        assert!(e_hw < e_sw * 2.0, "pow2 {e_hw} vs free {e_sw}");
    }

    #[test]
    fn mse_weight_schemes_not_worse_than_maxabs() {
        let (x, w, stats) = make(32, 96, 24, false, 7);
        let f = Fp8Format::E4M3Gaudi2;
        let pt = QuantizedLinear::prepare(&w, Some(&stats), QuantScheme::per_tensor(f));
        let mse_scheme = QuantScheme {
            weight: WeightScaling::MsePerTensor(crate::quant::ScaleSet::Arbitrary),
            ..QuantScheme::per_tensor(f)
        };
        let mse = QuantizedLinear::prepare(&w, Some(&stats), mse_scheme);
        assert!(mse.relative_error(&w, &x) <= pt.relative_error(&w, &x) * 1.05);
    }

    #[test]
    fn stochastic_rounding_unbiased_but_noisier() {
        let (x, w, stats) = make(64, 256, 16, false, 8);
        let f = Fp8Format::E4M3;
        let rne = QuantizedLinear::prepare(&w, Some(&stats), QuantScheme::per_tensor(f));
        let sr_scheme = QuantScheme {
            rounding: Rounding::Stochastic { seed: 99 },
            ..QuantScheme::per_tensor(f)
        };
        let sr = QuantizedLinear::prepare(&w, Some(&stats), sr_scheme);
        let (e_rne, e_sr) = (rne.relative_error(&w, &x), sr.relative_error(&w, &x));
        // Paper: SR "introduces increased quantization noise".
        assert!(e_sr > e_rne * 0.9, "rne {e_rne} sr {e_sr}");
        assert!(e_sr < e_rne * 3.0, "sr noise bounded: {e_sr} vs {e_rne}");
    }

    #[test]
    fn schemes_carry_kv_dtype() {
        let f = Fp8Format::E4M3Gaudi2;
        // Paper default: KV stored in the scheme's FP8 format.
        assert_eq!(QuantScheme::per_tensor(f).kv_dtype, KvDtype::Fp8(f));
        assert_eq!(QuantScheme::per_channel(f).kv_dtype, KvDtype::Fp8(f));
        let hi = QuantScheme::per_tensor(f).with_kv_dtype(KvDtype::F32);
        assert_eq!(hi.kv_dtype, KvDtype::F32);
        assert_eq!(hi.label(), "Per Tensor Scaling"); // label unaffected
    }

    #[test]
    fn labels_match_paper_tables() {
        let f = Fp8Format::E4M3Gaudi2;
        assert_eq!(QuantScheme::unit_scale(f).label(), "Unit Scale");
        assert_eq!(QuantScheme::per_tensor(f).label(), "Per Tensor Scaling");
        assert_eq!(QuantScheme::per_channel(f).label(), "Per Channel Scaling");
        assert_eq!(QuantScheme::smoothquant(f, 0.5).label(), "SmoothQuant");
    }

    #[test]
    fn gaudi3_format_no_worse_than_gaudi2() {
        // Wider range (448 vs 240) → per-tensor error should not increase.
        let (x, w, stats) = make(32, 128, 16, true, 9);
        let g2 = QuantizedLinear::prepare(
            &w,
            Some(&stats),
            QuantScheme::per_tensor(Fp8Format::E4M3Gaudi2),
        );
        let g3 =
            QuantizedLinear::prepare(&w, Some(&stats), QuantScheme::per_tensor(Fp8Format::E4M3));
        let (e2, e3) = (g2.relative_error(&w, &x), g3.relative_error(&w, &x));
        assert!(e3 <= e2 * 1.1, "g3 {e3} vs g2 {e2}");
    }
}
