//! SmoothQuant (paper §3.2.7, Eqs. 26–30): jointly scale activations and
//! weights along the common (input-channel) dimension, migrating the
//! quantization difficulty of activation-outlier channels into the weights.

use crate::fp8::Fp8Format;
use crate::quant::scale::round_scale_pow2;
use crate::tensor::Tensor2;

/// Output of the SmoothQuant scale computation.
#[derive(Clone, Debug)]
pub struct SmoothQuantResult {
    /// Common-dimension scales `s_c` (length C_l). Activations are divided
    /// by these per-channel; weights are multiplied per-input-channel.
    pub s_c: Vec<f32>,
    /// Per-tensor activation scale `s_x` (Eq. 26b) on the smoothed stats.
    pub s_x: f32,
    /// Weight scales on the smoothed weights: per-output-channel (Eq. 29b)
    /// or per-tensor (Eq. 30b) depending on `per_channel_weights`.
    pub s_w: Vec<f32>,
}

/// Compute SmoothQuant scales.
///
/// * `r_x_cols` — per-channel activation max-abs from calibration (Eq. 8b);
/// * `w` — the weight matrix (C_{l+1} × C_l);
/// * `alpha` — migration strength ∈ [0,1] (Eq. 26a);
/// * `backoff` — β for the activation scale;
/// * `per_channel_weights` — Eq. 29 (true) vs Eq. 30 (false);
/// * `pow2` — round `s_c` entries to powers of two (Eq. 14) for cheap
///   application.
pub fn smoothquant_scales(
    r_x_cols: &[f32],
    w: &Tensor2,
    alpha: f32,
    backoff: f32,
    format: Fp8Format,
    per_channel_weights: bool,
    pow2: bool,
) -> SmoothQuantResult {
    assert_eq!(r_x_cols.len(), w.cols, "channel count mismatch");
    let r_q = format.r_q();

    // Per-input-channel weight stats r_w| (Eq. 10c).
    let r_w_cols = crate::tensor::col_abs_max(w);

    // Eq. 26a: s_c[j] = r_x|[j]^α / r_w|[j]^(1-α).
    let mut s_c: Vec<f32> = r_x_cols
        .iter()
        .zip(&r_w_cols)
        .map(|(rx, rw)| {
            let (rx, rw) = (rx.max(1e-10), rw.max(1e-10));
            let s = rx.powf(alpha) / rw.powf(1.0 - alpha);
            if s.is_finite() && s > 0.0 {
                s
            } else {
                1.0
            }
        })
        .collect();
    if pow2 {
        for s in &mut s_c {
            *s = round_scale_pow2(*s);
        }
    }

    // Eq. 26b: s_x = max_j (r_x|[j] / s_c[j]) / (β r_q).
    let smoothed_max = r_x_cols
        .iter()
        .zip(&s_c)
        .map(|(rx, sc)| rx / sc)
        .fold(0.0f32, f32::max);
    let s_x = {
        let s = smoothed_max / (backoff * r_q);
        if s.is_finite() && s > 0.0 {
            s
        } else {
            1.0
        }
    };

    // Smoothed weights W̄ᵀ = S_c Wᵀ → rows of W scaled per *input* channel
    // (Eq. 28), then weight scales from the updated stats.
    let w_bar = w.scale_cols(&s_c);
    let s_w = if per_channel_weights {
        // Eq. 29: per-output-channel on W̄.
        crate::tensor::row_abs_max(&w_bar)
            .into_iter()
            .map(|r| {
                let s = r / r_q;
                if s.is_finite() && s > 0.0 {
                    s
                } else {
                    1.0
                }
            })
            .collect()
    } else {
        // Eq. 30: per-tensor on W̄.
        let r = crate::tensor::abs_max(&w_bar);
        let s = r / r_q;
        vec![if s.is_finite() && s > 0.0 { s } else { 1.0 }]
    };

    SmoothQuantResult { s_c, s_x, s_w }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShiftRng;

    fn setup(outliers: bool) -> (Vec<f32>, Tensor2) {
        let mut rng = XorShiftRng::new(11);
        let x = if outliers {
            Tensor2::randn_outlier_cols(128, 64, 1.0, 0.08, 60.0, &mut rng)
        } else {
            Tensor2::randn(128, 64, 1.0, &mut rng)
        };
        let w = Tensor2::randn(32, 64, 0.05, &mut rng);
        (crate::tensor::col_abs_max(&x), w)
    }

    #[test]
    fn alpha_zero_matches_weight_stats() {
        // α=0 → s_c = 1/r_w| : all difficulty moved to activations.
        let (rx, w) = setup(false);
        let r = smoothquant_scales(&rx, &w, 0.0, 1.0, Fp8Format::E4M3, true, false);
        let rw = crate::tensor::col_abs_max(&w);
        for (s, rwj) in r.s_c.iter().zip(&rw) {
            assert!((s - 1.0 / rwj).abs() / (1.0 / rwj) < 1e-4);
        }
    }

    #[test]
    fn alpha_one_matches_act_stats() {
        // α=1 → s_c = r_x| : all difficulty moved into weights.
        let (rx, w) = setup(false);
        let r = smoothquant_scales(&rx, &w, 1.0, 1.0, Fp8Format::E4M3, true, false);
        for (s, rxj) in r.s_c.iter().zip(&rx) {
            assert!((s - rxj).abs() / rxj < 1e-4);
        }
    }

    #[test]
    fn smoothing_equalizes_activation_channels() {
        // After dividing by s_c (α=0.5), outlier channels shrink: the ratio
        // max_channel/median_channel of smoothed stats must drop sharply.
        let (rx, w) = setup(true);
        let r = smoothquant_scales(&rx, &w, 0.5, 1.0, Fp8Format::E4M3, true, false);
        let smoothed: Vec<f32> = rx.iter().zip(&r.s_c).map(|(x, s)| x / s).collect();
        let spread = |v: &[f32]| {
            let mut s = v.to_vec();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            s[s.len() - 1] / s[s.len() / 2]
        };
        assert!(
            spread(&smoothed) < spread(&rx) / 4.0,
            "raw spread {} smoothed {}",
            spread(&rx),
            spread(&smoothed)
        );
    }

    #[test]
    fn transform_is_mathematically_invisible() {
        // X·Wᵀ must be unchanged by inserting S_c⁻¹ S_c (before quantization).
        let mut rng = XorShiftRng::new(5);
        let x = Tensor2::randn(16, 64, 1.0, &mut rng);
        let w = Tensor2::randn(8, 64, 0.1, &mut rng);
        let rx = crate::tensor::col_abs_max(&x);
        let r = smoothquant_scales(&rx, &w, 0.5, 1.0, Fp8Format::E4M3, true, false);
        let ref_out = crate::tensor::matmul_nt(&x, &w);
        let inv: Vec<f32> = r.s_c.iter().map(|s| 1.0 / s).collect();
        let x_s = x.scale_cols(&inv);
        let w_s = w.scale_cols(&r.s_c);
        let out = crate::tensor::matmul_nt(&x_s, &w_s);
        for (a, b) in out.data.iter().zip(&ref_out.data) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1e-3), "{a} vs {b}");
        }
    }

    #[test]
    fn pow2_mode_emits_pow2_scales() {
        let (rx, w) = setup(true);
        let r = smoothquant_scales(&rx, &w, 0.5, 1.0, Fp8Format::E4M3, true, true);
        for s in &r.s_c {
            assert_eq!(s.log2().fract(), 0.0, "{s}");
        }
    }

    #[test]
    fn per_tensor_weight_mode_returns_single_scale() {
        let (rx, w) = setup(false);
        let r = smoothquant_scales(&rx, &w, 0.5, 1.0, Fp8Format::E4M3, false, false);
        assert_eq!(r.s_w.len(), 1);
        let rc = smoothquant_scales(&rx, &w, 0.5, 1.0, Fp8Format::E4M3, true, false);
        assert_eq!(rc.s_w.len(), w.rows);
    }

    #[test]
    fn degenerate_stats_do_not_poison() {
        let rx = vec![0.0f32; 8];
        let w = Tensor2::zeros(4, 8);
        let r = smoothquant_scales(&rx, &w, 0.5, 1.0, Fp8Format::E4M3, true, false);
        assert!(r.s_x.is_finite() && r.s_x > 0.0);
        assert!(r.s_c.iter().all(|s| s.is_finite() && *s > 0.0));
    }
}
