//! MSE-minimizing weight-scale search (paper §3.2.5 / §3.2.6, Eqs. 22 & 24).
//!
//! `s_w = argmin_{s ∈ 𝒮} ‖Wᵀ − s·Q(s⁻¹·Wᵀ)‖²` where the candidate set 𝒮
//! "can contain arbitrary scales, power-of-2 scales, or hardware-accelerated
//! scales" — all three are implemented.

use crate::fp8::{encode_rne, CastMode, DecodeTable, Fp8Format};
use crate::gaudisim::device::Generation;
use crate::quant::scale::{round_scale_pow2, weight_scale_per_tensor};

/// Candidate scale set 𝒮.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScaleSet {
    /// Multiplicative grid around the max-abs scale: s_max · 2^(i/steps)
    /// for i in [-range·steps, +steps].
    Arbitrary,
    /// All powers of two within ±8 octaves of the max-abs scale.
    Pow2,
    /// The generation's hardware-accelerated exponent set (§2.4).
    HwAccelerated(Generation),
}

/// Quantization MSE of a row under scale `s`.
fn row_mse(row: &[f32], s: f32, table: &DecodeTable, format: Fp8Format) -> f64 {
    let inv = 1.0 / s;
    let mut acc = 0.0f64;
    for &w in row {
        let q = table.get(encode_rne(w * inv, format, CastMode::SatFinite));
        let err = (q * s - w) as f64;
        acc += err * err;
    }
    acc
}

fn candidates(s_max: f32, set: ScaleSet) -> Vec<f32> {
    match set {
        ScaleSet::Arbitrary => {
            // 33 candidates spanning [s_max/8, s_max·2] on a log grid —
            // finer near s_max where the optimum usually sits.
            (-24..=8)
                .map(|i| s_max * (2.0f32).powf(i as f32 / 8.0))
                .collect()
        }
        ScaleSet::Pow2 => {
            let center = round_scale_pow2(s_max).log2() as i32;
            (center - 8..=center + 2).map(|e| (2.0f32).powi(e)).collect()
        }
        ScaleSet::HwAccelerated(generation) => crate::fp8::hw_scale_exponents(generation)
            .into_iter()
            .map(|e| (2.0f32).powi(e))
            .collect(),
    }
}

/// Eq. 22: per-tensor MSE scale for a weight matrix (rows = output channels).
pub fn mse_scale_per_tensor(rows: &[&[f32]], format: Fp8Format, set: ScaleSet) -> f32 {
    let table = DecodeTable::new(format);
    let r_w = rows
        .iter()
        .flat_map(|r| r.iter())
        .fold(0.0f32, |m, x| m.max(x.abs()));
    let s_max = weight_scale_per_tensor(r_w, format);
    let mut best = (f64::INFINITY, s_max);
    for s in candidates(s_max, set) {
        let mse: f64 = rows.iter().map(|r| row_mse(r, s, &table, format)).sum();
        if mse < best.0 {
            best = (mse, s);
        }
    }
    best.1
}

/// Eq. 24: independent per-output-channel MSE scales.
pub fn mse_scale_per_channel(rows: &[&[f32]], format: Fp8Format, set: ScaleSet) -> Vec<f32> {
    let table = DecodeTable::new(format);
    rows.iter()
        .map(|row| {
            let r = row.iter().fold(0.0f32, |m, x| m.max(x.abs()));
            let s_max = weight_scale_per_tensor(r, format);
            let mut best = (f64::INFINITY, s_max);
            for s in candidates(s_max, set) {
                let mse = row_mse(row, s, &table, format);
                if mse < best.0 {
                    best = (mse, s);
                }
            }
            best.1
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor2;
    use crate::util::rng::XorShiftRng;

    fn quant_mse(rows: &[&[f32]], s: f32, format: Fp8Format) -> f64 {
        let table = DecodeTable::new(format);
        rows.iter().map(|r| row_mse(r, s, &table, format)).sum()
    }

    #[test]
    fn mse_search_beats_maxabs_scale() {
        // With Gaussian weights (no outliers at the max), shrinking the
        // scale below max-abs trades rare clipping for finer resolution —
        // the search must find something at least as good.
        let mut rng = XorShiftRng::new(42);
        let w = Tensor2::randn(16, 256, 0.02, &mut rng);
        let rows: Vec<&[f32]> = (0..w.rows).map(|r| w.row(r)).collect();
        let f = Fp8Format::E4M3;
        let r_w = crate::tensor::abs_max(&w);
        let s_maxabs = weight_scale_per_tensor(r_w, f);
        let s_opt = mse_scale_per_tensor(&rows, f, ScaleSet::Arbitrary);
        let mse_maxabs = quant_mse(&rows, s_maxabs, f);
        let mse_opt = quant_mse(&rows, s_opt, f);
        assert!(
            mse_opt <= mse_maxabs * 1.0001,
            "opt {mse_opt} vs maxabs {mse_maxabs}"
        );
    }

    #[test]
    fn per_channel_mse_beats_per_tensor_mse() {
        // Rows with very different magnitudes: per-channel wins (the
        // motivation for §3.2.6 / Table 2-4's per-channel advantage).
        // Total MSE is dominated by the hot row (identical either way), so
        // the decisive comparison is on the *cold* rows, whose resolution
        // per-tensor scaling sacrifices to the hot row.
        let mut rng = XorShiftRng::new(7);
        let mut w = Tensor2::randn(8, 128, 1.0, &mut rng);
        for c in 0..w.cols {
            let v = w.get(7, c);
            w.set(7, c, v * 100.0); // one hot channel
        }
        let rows: Vec<&[f32]> = (0..w.rows).map(|r| w.row(r)).collect();
        let f = Fp8Format::E4M3Gaudi2;
        let s_t = mse_scale_per_tensor(&rows, f, ScaleSet::Arbitrary);
        let s_c = mse_scale_per_channel(&rows, f, ScaleSet::Arbitrary);
        let table = DecodeTable::new(f);
        let cold_t: f64 = rows[..7].iter().map(|r| row_mse(r, s_t, &table, f)).sum();
        let cold_c: f64 = rows[..7]
            .iter()
            .zip(&s_c[..7])
            .map(|(r, s)| row_mse(r, *s, &table, f))
            .sum();
        // FP8's wide dynamic range keeps the gap modest (precision is
        // relative, so a 100× magnitude spread does not underflow) — exactly
        // why the paper finds per-channel only a "slight advantage" over
        // per-tensor. The win must still be strict and material.
        assert!(
            cold_c < cold_t * 0.9,
            "cold-row MSE per-channel {cold_c} vs per-tensor {cold_t}"
        );
        // And the hot row is no worse.
        let hot_t = row_mse(rows[7], s_t, &table, f);
        let hot_c = row_mse(rows[7], s_c[7], &table, f);
        assert!(hot_c <= hot_t * 1.0001);
    }

    #[test]
    fn pow2_candidates_are_pow2() {
        for s in candidates(0.013, ScaleSet::Pow2) {
            assert_eq!(s.log2().fract(), 0.0, "{s}");
        }
    }

    #[test]
    fn hw_set_respects_generation() {
        let g2 = candidates(1.0, ScaleSet::HwAccelerated(Generation::Gaudi2));
        assert_eq!(g2.len(), 4);
        let g3 = candidates(1.0, ScaleSet::HwAccelerated(Generation::Gaudi3));
        assert_eq!(g3.len(), 64);
    }

    #[test]
    fn hw_constrained_search_no_better_than_free_search() {
        let mut rng = XorShiftRng::new(3);
        let w = Tensor2::randn(4, 256, 0.5, &mut rng);
        let rows: Vec<&[f32]> = (0..w.rows).map(|r| w.row(r)).collect();
        let f = Fp8Format::E4M3Gaudi2;
        let free = quant_mse(&rows, mse_scale_per_tensor(&rows, f, ScaleSet::Arbitrary), f);
        let pow2 = quant_mse(&rows, mse_scale_per_tensor(&rows, f, ScaleSet::Pow2), f);
        let hw = quant_mse(
            &rows,
            mse_scale_per_tensor(&rows, f, ScaleSet::HwAccelerated(Generation::Gaudi2)),
            f,
        );
        assert!(free <= pow2 * 1.0001);
        assert!(pow2 <= hw * 1.0001);
    }

    #[test]
    fn zero_weights_quantize_exactly() {
        let z = vec![0.0f32; 64];
        let rows: Vec<&[f32]> = vec![&z];
        let f = Fp8Format::E4M3;
        let s = mse_scale_per_tensor(&rows, f, ScaleSet::Arbitrary);
        assert_eq!(quant_mse(&rows, s, f), 0.0);
    }
}
