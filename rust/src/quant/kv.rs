//! KV-cache dtype and layout — the single byte-accounting contract for the
//! whole serving stack.
//!
//! The paper's Table 6 OOM frontier assumes the KV cache is stored in FP8
//! (1 B/elem): "thanks to the memory gain, we can measure Llama 70B on a
//! single Gaudi 2". Before this module existed, three components modelled
//! what a KV token costs independently (the coordinator's host store at
//! 4 B/elem, the gaudisim capacity model at 1 B/elem, the fleet replicas at
//! whatever they were handed) and silently disagreed. Now every consumer —
//! [`crate::coordinator::BlockAllocator`] (admission),
//! `gaudisim::MemoryModel` (the Table 6 frontier), `router::SimReplica`
//! (fleet admission), and the engine's host `KvStore` (actual storage) —
//! derives bytes/token from one [`KvLayout`].

use crate::fp8::Fp8Format;

/// Physical paging granularity of the KV subsystem, in tokens per block.
/// One constant feeds every consumer — the paged `KvStore` pool, the radix
/// `PrefixCache` (prefixes are shared at whole-block granularity), and the
/// block-quantized capacity model — so "a block" can never mean two
/// different things on two sides of an interface.
pub const KV_BLOCK_TOKENS: usize = 16;

/// FP8 scale metadata is stored per (layer, kv-head) *group*: one slot for K
/// and one for V. This names the `2 *` that would otherwise float around the
/// byte arithmetic below and in the paged pool's read accounting.
pub const KV_SCALE_SLOTS_PER_GROUP: usize = 2;

/// Each FP8 max-abs scale is a host-side f32.
pub const KV_SCALE_BYTES: usize = std::mem::size_of::<f32>();

/// Bytes of scale metadata one (layer, kv-head) group carries: K-scale plus
/// V-scale. The paged pool charges this per block head-pair read on the FP8
/// path (`BlockPool::block_read_bytes_per_head`).
pub const FP8_SCALE_GROUP_BYTES: usize = KV_SCALE_SLOTS_PER_GROUP * KV_SCALE_BYTES;

/// Storage element type of the KV cache.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KvDtype {
    /// Full-precision host storage (the legacy exact-roundtrip behavior).
    F32,
    /// BF16 storage: 2 B/elem, RNE-rounded, no scales needed (the KV value
    /// range sits comfortably inside BF16's).
    Bf16,
    /// FP8 codes + per-(slot, layer, kv-head) max-abs f32 scales. This is
    /// the paper's serving configuration and what the Table 6 grid needs
    /// to fit in 96 GB.
    Fp8(Fp8Format),
}

impl KvDtype {
    /// The paper's serving target: Gaudi 2's E4M3 (±240).
    pub const FP8_DEFAULT: KvDtype = KvDtype::Fp8(Fp8Format::E4M3Gaudi2);

    /// Payload bytes per stored element.
    pub fn elem_bytes(self) -> usize {
        match self {
            KvDtype::F32 => 4,
            KvDtype::Bf16 => 2,
            KvDtype::Fp8(_) => 1,
        }
    }

    /// Short name used in CLI flags and bench JSON rows.
    pub fn name(self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::Bf16 => "bf16",
            KvDtype::Fp8(Fp8Format::E4M3Gaudi2) => "fp8_e4m3_gaudi2",
            KvDtype::Fp8(Fp8Format::E4M3) => "fp8_e4m3",
            KvDtype::Fp8(Fp8Format::E5M2) => "fp8_e5m2",
        }
    }

    /// Parse a CLI spelling. Bare `"fp8"` selects the Gaudi 2 E4M3 variant.
    pub fn parse(s: &str) -> Option<KvDtype> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => Some(KvDtype::F32),
            "bf16" | "bfloat16" => Some(KvDtype::Bf16),
            "fp8" | "fp8_e4m3_gaudi2" => Some(KvDtype::Fp8(Fp8Format::E4M3Gaudi2)),
            "fp8_e4m3" => Some(KvDtype::Fp8(Fp8Format::E4M3)),
            "fp8_e5m2" => Some(KvDtype::Fp8(Fp8Format::E5M2)),
            _ => None,
        }
    }
}

/// The KV-cache accounting contract.
///
/// `bytes_per_token()` is the payload rate every capacity consumer charges.
/// FP8 additionally stores one f32 max-abs scale per (layer, kv-head) group
/// per sequence for each of K and V ([`Self::scale_bytes_per_seq`]); at
/// well under 0.01% of any realistic sequence payload it is charged against
/// the fixed workspace reserve rather than the per-token rate, which keeps
/// the Table 6 frontier bit-exact and KV byte counts linear in tokens.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvLayout {
    pub dtype: KvDtype,
    pub layers: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
}

impl KvLayout {
    pub fn new(dtype: KvDtype, layers: usize, kv_heads: usize, head_dim: usize) -> Self {
        Self {
            dtype,
            layers,
            kv_heads,
            head_dim,
        }
    }

    /// K+V elements one token adds across all layers.
    pub fn elems_per_token(&self) -> usize {
        2 * self.layers * self.kv_heads * self.head_dim
    }

    /// Payload bytes per token — the shared accounting rate.
    pub fn bytes_per_token(&self) -> usize {
        self.elems_per_token() * self.dtype.elem_bytes()
    }

    /// Per-sequence scale metadata (FP8 only): one f32 per (layer, kv-head)
    /// group for each of K and V.
    pub fn scale_bytes_per_seq(&self) -> usize {
        match self.dtype {
            KvDtype::Fp8(_) => self.layers * self.kv_heads * FP8_SCALE_GROUP_BYTES,
            _ => 0,
        }
    }

    /// Exact storage for one sequence of `tokens` (payload + scales).
    pub fn seq_bytes(&self, tokens: usize) -> usize {
        tokens * self.bytes_per_token() + self.scale_bytes_per_seq()
    }

    /// Per-block FP8 scale metadata in the paged pool: one f32 per
    /// (layer, kv-head) group for each of K and V, per physical block.
    pub fn scale_bytes_per_block(&self) -> usize {
        match self.dtype {
            KvDtype::Fp8(_) => self.layers * self.kv_heads * FP8_SCALE_GROUP_BYTES,
            _ => 0,
        }
    }

    /// Exact bytes of one physical pool block of `block_tokens` tokens
    /// (payload + block-granular scales).
    pub fn block_bytes(&self, block_tokens: usize) -> usize {
        block_tokens * self.bytes_per_token() + self.scale_bytes_per_block()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elem_bytes_per_dtype() {
        assert_eq!(KvDtype::F32.elem_bytes(), 4);
        assert_eq!(KvDtype::Bf16.elem_bytes(), 2);
        for f in Fp8Format::ALL {
            assert_eq!(KvDtype::Fp8(f).elem_bytes(), 1);
        }
    }

    #[test]
    fn llama70b_fp8_rate_matches_table6_accounting() {
        // 2 · 80 layers · 8 kv-heads · 128 dim · 1 B = 163840 B/token.
        let l = KvLayout::new(KvDtype::FP8_DEFAULT, 80, 8, 128);
        assert_eq!(l.bytes_per_token(), 163_840);
        let f32_l = KvLayout::new(KvDtype::F32, 80, 8, 128);
        assert_eq!(f32_l.bytes_per_token(), 4 * l.bytes_per_token());
        let bf16_l = KvLayout::new(KvDtype::Bf16, 80, 8, 128);
        assert_eq!(bf16_l.bytes_per_token(), 2 * l.bytes_per_token());
    }

    #[test]
    fn scale_overhead_is_per_seq_and_negligible() {
        let l = KvLayout::new(KvDtype::FP8_DEFAULT, 80, 8, 128);
        assert_eq!(l.scale_bytes_per_seq(), 2 * 80 * 8 * 4);
        assert_eq!(KvLayout::new(KvDtype::F32, 80, 8, 128).scale_bytes_per_seq(), 0);
        // < 0.01% of a 512-token sequence's payload.
        let payload = 512 * l.bytes_per_token();
        assert!((l.scale_bytes_per_seq() as f64) < 1e-4 * payload as f64);
        assert_eq!(l.seq_bytes(512), payload + l.scale_bytes_per_seq());
    }

    #[test]
    fn block_bytes_cover_payload_plus_block_scales() {
        let l = KvLayout::new(KvDtype::FP8_DEFAULT, 80, 8, 128);
        assert_eq!(l.scale_bytes_per_block(), 2 * 80 * 8 * 4);
        assert_eq!(
            l.block_bytes(KV_BLOCK_TOKENS),
            KV_BLOCK_TOKENS * l.bytes_per_token() + l.scale_bytes_per_block()
        );
        // Per-block scale metadata stays far below 1% of a 16-token
        // 70B-geometry block's payload.
        assert!(
            (l.scale_bytes_per_block() as f64)
                < 0.01 * (KV_BLOCK_TOKENS * l.bytes_per_token()) as f64
        );
        // Scale-free dtypes pay payload only.
        let f = KvLayout::new(KvDtype::F32, 80, 8, 128);
        assert_eq!(f.block_bytes(16), 16 * f.bytes_per_token());
    }

    #[test]
    fn scale_constants_preserve_legacy_literals() {
        // The named constants must re-derive exactly what the old inline
        // literals (`2 * layers * kv_heads * 4`, and the pool's `2 * 4`
        // per-head-pair read charge) computed, or every Table 5/6 byte
        // assertion downstream would shift.
        assert_eq!(KV_SCALE_SLOTS_PER_GROUP, 2);
        assert_eq!(KV_SCALE_BYTES, 4);
        assert_eq!(FP8_SCALE_GROUP_BYTES, 2 * 4);
        let l = KvLayout::new(KvDtype::FP8_DEFAULT, 80, 8, 128);
        assert_eq!(l.scale_bytes_per_seq(), 2 * 80 * 8 * 4);
        assert_eq!(l.scale_bytes_per_block(), 2 * 80 * 8 * 4);
    }

    #[test]
    fn parse_roundtrips_names() {
        for d in [
            KvDtype::F32,
            KvDtype::Bf16,
            KvDtype::Fp8(Fp8Format::E4M3Gaudi2),
            KvDtype::Fp8(Fp8Format::E4M3),
            KvDtype::Fp8(Fp8Format::E5M2),
        ] {
            assert_eq!(KvDtype::parse(d.name()), Some(d), "{}", d.name());
        }
        assert_eq!(KvDtype::parse("fp8"), Some(KvDtype::FP8_DEFAULT));
        assert_eq!(KvDtype::parse("FP8"), Some(KvDtype::FP8_DEFAULT));
        assert_eq!(KvDtype::parse("int8"), None);
    }
}
