//! Quantization toolkit — every scaling method of paper §3.2 and the §3.3
//! recipe.
//!
//! Naming follows the paper exactly:
//! * `s_x` — activation (input) scales, per-tensor (Eq. 15) or per-sample
//!   (Eq. 17);
//! * `s_w` — weight scales, per-tensor (Eq. 18) or per-output-channel
//!   (Eq. 20), optionally MSE-optimized over a scale set 𝒮 (Eqs. 22, 24);
//! * `s_c` — common-dimension scales, unit except for SmoothQuant (Eq. 26);
//! * `β` — the backoff factor that leaves headroom above the calibrated max;
//! * `r_q` — the largest representable magnitude of the FP8 format.
//!
//! The quantized linear is Eq. 2:
//! `X_{l+1} = S_x ( Q(S_x⁻¹ X S_c⁻¹) ⊗ Q(S_c Wᵀ S_w⁻¹) ) S_w`.

pub mod kv;
pub mod recipe;
pub mod scale;
pub mod search;
pub mod smoothquant;

pub use kv::{KvDtype, KvLayout, KV_BLOCK_TOKENS};
pub use recipe::{QuantScheme, QuantizedLinear, Rounding};
pub use scale::{
    act_scale_per_sample, act_scale_per_tensor, round_scale_pow2, weight_scale_per_channel,
    weight_scale_per_tensor, ActScaling, WeightScaling,
};
pub use search::{mse_scale_per_channel, mse_scale_per_tensor, ScaleSet};
pub use smoothquant::{smoothquant_scales, SmoothQuantResult};

/// Default backoff factor β (headroom for values beyond the calibration max).
pub const DEFAULT_BACKOFF: f32 = 1.0;
