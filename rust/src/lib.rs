//! # gaudi-fp8 — Faster Inference of LLMs using FP8 (Intel Gaudi), reproduced
//!
//! A from-scratch reproduction of the paper's full system as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * [`fp8`] — bit-exact software emulation of the Gaudi 2/3 FP8 formats
//!   (E4M3 ±240 / ±448, E5M2), RNE + stochastic rounding, and the
//!   hardware power-of-two exponent-bias rescaling trick.
//! * [`tensor`] — minimal dense 2-D tensor with the reductions the paper's
//!   calibration equations need.
//! * [`quant`] — every scaling method in §3.2: per-tensor / per-sample
//!   activations, per-tensor / per-output-channel weights, MSE scale search
//!   over arbitrary / pow2 / hardware-accelerated scale sets, SmoothQuant,
//!   unit scale, backoff, pow2 rounding; plus the §3.3 quantization recipe.
//! * [`calib`] — statistics collectors and the calibration runner (§3.1).
//! * [`gemm`] — the scaled FP8 GEMM reference (Eq. 2): quantize → multiply →
//!   FP32 accumulate → descale, bit-exact against the Pallas kernel.
//! * [`gaudisim`] — analytical Gaudi 2/3 performance model (MME roofline,
//!   HBM bandwidth/capacity, pow2 fast path) regenerating Tables 1, 5, 6.
//! * [`model`] — LLM config zoo (Llama2/3, Mistral, Mixtral + synthetic
//!   scales), parameter/FLOPs/KV accounting, synthetic-statistics models.
//! * [`runtime`] — PJRT loader/executor for the AOT artifacts produced by
//!   `python/compile/aot.py`.
//! * [`coordinator`] — the serving layer: continuous batcher, KV-cache block
//!   allocator, prefill/decode scheduler, metrics.
//! * [`router`] — the fleet layer: multi-replica load balancing (replica
//!   registry with health/drain state, routing policies, bounded admission
//!   with typed rejects, fleet-merged metrics) over [`coordinator::Engine`]
//!   replicas or gaudisim-backed simulated replicas.
//! * [`obs`] — observability: per-replica trace recorders of typed request
//!   lifecycle events (Chrome trace-event / Perfetto export), step-level
//!   MFU and KV-bytes accounting, and Prometheus text exposition.
//! * [`eval`] — accuracy harness (perplexity, KL, top-1 agreement) emitting
//!   the paper's Δ% tables.
//! * [`server`] — CLI plumbing for the `repro` binary.
//! * [`util`] — dependency-free RNG / property-testing / bench / JSON
//!   utilities (the usual crates are unreachable in this offline build).
//!
//! See DESIGN.md for the paper → module map and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod calib;
pub mod coordinator;
pub mod eval;
pub mod fp8;
pub mod gaudisim;
pub mod gemm;
pub mod model;
pub mod obs;
pub mod quant;
pub mod router;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod util;
