//! Hardware-accelerated power-of-two scaling (paper §2.4) and product tables
//! for the emulated GEMM hot path.
//!
//! On Gaudi, when both GEMM inputs use per-tensor power-of-two scales, the
//! scaling is folded into the MME's exponent bias instead of multiplying
//! elements — worth several percent of throughput (Table 1). We model the
//! *numeric* side here: [`rescale_pow2`] adjusts an FP8 code's exponent field
//! directly, and [`hw_scale_exponents`] lists the scale sets each generation
//! accelerates (Gaudi 2: {2⁻⁸, 2⁻⁴, 2⁰, 2⁴}; Gaudi 3: 2⁻³²…2³¹).

use std::sync::OnceLock;

use super::decode::{decode, DecodeTable};
use super::encode::{encode_rne, CastMode};
use super::format::Fp8Format;
use crate::gaudisim::device::Generation;

/// Process-wide decode LUT for `format`, built lazily on first use.
/// `OnceLock` (not `lazy_static`/`Mutex`) so a panic elsewhere can never
/// poison it, and repeated lookups are a single atomic load. This is the
/// table the paged KV read path indexes per code — one 256-entry f32
/// table, one scale multiply per 16-token tile — replacing per-element
/// exponent math on the decode hot path.
pub fn decode_table(format: Fp8Format) -> &'static DecodeTable {
    static E4M3_GAUDI2: OnceLock<DecodeTable> = OnceLock::new();
    static E4M3: OnceLock<DecodeTable> = OnceLock::new();
    static E5M2: OnceLock<DecodeTable> = OnceLock::new();
    let slot = match format {
        Fp8Format::E4M3Gaudi2 => &E4M3_GAUDI2,
        Fp8Format::E4M3 => &E4M3,
        Fp8Format::E5M2 => &E5M2,
    };
    slot.get_or_init(|| DecodeTable::new(format))
}

/// Decode one code through the shared LUT. Exactly equal (bit-for-bit) to
/// [`decode`] for every code — the table is built from it.
#[inline]
pub fn decode_lut(code: u8, format: Fp8Format) -> f32 {
    decode_table(format).get(code)
}

/// Exponents `k` such that scale `2^k` is hardware-accelerated (exponent-bias
/// adjustment, no per-element multiply) on the given Gaudi generation.
pub fn hw_scale_exponents(generation: Generation) -> Vec<i32> {
    match generation {
        Generation::Gaudi2 => vec![-8, -4, 0, 4],
        Generation::Gaudi3 => (-32..=31).collect(),
    }
}

/// Is `s` a hardware-accelerated scale on `generation`?
pub fn is_hw_accelerated_scale(s: f32, generation: Generation) -> bool {
    if s <= 0.0 || !s.is_finite() {
        return false;
    }
    let l = s.log2();
    if l.fract() != 0.0 {
        return false;
    }
    hw_scale_exponents(generation).contains(&(l as i32))
}

/// Multiply a quantized FP8 value by 2^k *in the code domain* — the
/// exponent-bias trick. Saturates/flushes exactly as a decode → scale →
/// re-encode would. Returns the rescaled code.
pub fn rescale_pow2(code: u8, k: i32, format: Fp8Format) -> u8 {
    let p = format.params();
    match format.classify(code) {
        super::format::SpecialCase::Nan
        | super::format::SpecialCase::Inf
        | super::format::SpecialCase::Zero => return code,
        _ => {}
    }
    let sign = code & 0x80;
    let man_mask = (1u8 << p.man_bits) - 1;
    let exp = ((code >> p.man_bits) & ((1 << p.exp_bits) - 1)) as i32;
    if exp != 0 {
        let new_exp = exp + k;
        let max_exp = ((p.max_code >> p.man_bits) & ((1 << p.exp_bits) - 1)) as i32;
        let man = code & man_mask;
        if new_exp > max_exp || (new_exp == max_exp && man > (p.max_code & man_mask)) {
            return sign | p.max_code; // saturate
        }
        if new_exp >= 1 {
            return sign | ((new_exp as u8) << p.man_bits) | man;
        }
        // Falls into the subnormal range: shift the (implicit-1) mantissa.
        let full_man = (1u32 << p.man_bits) | man as u32; // 1.mmm as integer
        let shift = 1 - new_exp; // ≥ 1
        if shift > p.man_bits as i32 + 1 {
            return sign; // underflow to zero (RNE of the exact value)
        }
        // Round-to-nearest-even the shifted mantissa.
        let kept = full_man >> shift;
        let rem = full_man & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let rounded = match rem.cmp(&half) {
            std::cmp::Ordering::Less => kept,
            std::cmp::Ordering::Greater => kept + 1,
            std::cmp::Ordering::Equal => kept + (kept & 1),
        };
        // rounded may reach 2^man_bits → that's the min normal, uniform code.
        return sign | rounded as u8;
    }
    // Subnormal source: exact value is man * 2^(1-bias-M); scaling by 2^k
    // shifts it. Re-encode via the exact arithmetic path (cheap; subnormals
    // are rare on the GEMM path).
    let v = decode(code, format) * (2.0f32).powi(k);
    encode_rne(v, format, CastMode::SatFinite)
}

/// 256×256 product table: `table[a][b] = decode(a) * decode(b)` as f32.
/// 256 KiB; fits in L2. This is the emulated-GEMM inner-loop trick: one load
/// replaces two decodes and a multiply. Specials (NaN/Inf) decode to f32
/// specials and propagate through the f32 accumulation naturally.
pub struct Fp8Gemm8x8 {
    pub products: Vec<f32>, // 65536 entries, row-major [a][b]
}

impl Fp8Gemm8x8 {
    pub fn new(fa: Fp8Format, fb: Fp8Format) -> Self {
        let ta = DecodeTable::new(fa);
        let tb = DecodeTable::new(fb);
        let mut products = vec![0.0f32; 65536];
        for a in 0..256usize {
            let va = ta.values[a];
            for b in 0..256usize {
                products[(a << 8) | b] = va * tb.values[b];
            }
        }
        Self { products }
    }

    #[inline]
    pub fn mul(&self, a: u8, b: u8) -> f32 {
        // Safety: index is always < 65536 by construction.
        unsafe { *self.products.get_unchecked(((a as usize) << 8) | b as usize) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_lut_matches_scalar_decode_for_all_256_codes() {
        // Exhaustive scalar-vs-LUT equivalence, every format: the shared
        // OnceLock table must be bit-identical to fp8::decode everywhere
        // (NaN compares as NaN; zeros keep their sign).
        for f in Fp8Format::ALL {
            for c in 0u16..=255 {
                let c = c as u8;
                let scalar = decode(c, f);
                let lut = decode_lut(c, f);
                assert!(
                    (scalar.is_nan() && lut.is_nan()) || scalar.to_bits() == lut.to_bits(),
                    "format {f:?} code {c:#04x}: scalar {scalar} lut {lut}"
                );
            }
            // And the returned table is the cached instance, not a rebuild.
            assert!(std::ptr::eq(decode_table(f), decode_table(f)));
        }
    }

    #[test]
    fn hw_scale_sets_match_paper() {
        assert_eq!(hw_scale_exponents(Generation::Gaudi2), vec![-8, -4, 0, 4]);
        let g3 = hw_scale_exponents(Generation::Gaudi3);
        assert_eq!(g3.first(), Some(&-32));
        assert_eq!(g3.last(), Some(&31));
        assert_eq!(g3.len(), 64);
    }

    #[test]
    fn hw_accel_predicate() {
        assert!(is_hw_accelerated_scale(1.0, Generation::Gaudi2));
        assert!(is_hw_accelerated_scale(0.0625, Generation::Gaudi2)); // 2^-4
        assert!(!is_hw_accelerated_scale(0.5, Generation::Gaudi2)); // 2^-1 not in set
        assert!(is_hw_accelerated_scale(0.5, Generation::Gaudi3));
        assert!(!is_hw_accelerated_scale(3.0, Generation::Gaudi3)); // not pow2
        assert!(!is_hw_accelerated_scale(-2.0, Generation::Gaudi3));
        assert!(is_hw_accelerated_scale((2.0f32).powi(-32), Generation::Gaudi3));
        assert!(!is_hw_accelerated_scale((2.0f32).powi(-33), Generation::Gaudi3));
    }

    #[test]
    fn rescale_matches_decode_scale_encode_exhaustive() {
        // For every code and a sweep of k, the code-domain rescale must agree
        // with the arithmetic route decode → ×2^k → RNE encode.
        for f in Fp8Format::ALL {
            for k in [-10, -4, -1, 0, 1, 4, 6] {
                for c in 0u16..=255 {
                    let c = c as u8;
                    let fast = rescale_pow2(c, k, f);
                    let v = decode(c, f);
                    if v.is_nan() {
                        assert!(decode(fast, f).is_nan());
                        continue;
                    }
                    if v.is_infinite() {
                        assert_eq!(fast, c);
                        continue;
                    }
                    let slow = encode_rne(v * (2.0f32).powi(k), f, CastMode::SatFinite);
                    let (vf, vs) = (decode(fast, f), decode(slow, f));
                    assert!(
                        vf == vs && (vf != 0.0 || (fast & 0x80) == (slow & 0x80)),
                        "format {f:?} k={k} code {c:#04x} ({v}): fast {vf} slow {vs}"
                    );
                }
            }
        }
    }

    #[test]
    fn rescale_zero_and_specials_unchanged() {
        for f in Fp8Format::ALL {
            assert_eq!(rescale_pow2(0x00, 4, f), 0x00);
            assert_eq!(rescale_pow2(0x80, -4, f), 0x80);
            let nan = f.params().nan_code;
            assert!(decode(rescale_pow2(nan, 4, f), f).is_nan());
        }
    }

    #[test]
    fn product_table_matches_scalar() {
        let g = Fp8Gemm8x8::new(Fp8Format::E4M3, Fp8Format::E4M3);
        let t = DecodeTable::new(Fp8Format::E4M3);
        let mut rng = crate::util::rng::XorShiftRng::new(2);
        for _ in 0..2000 {
            let a = (rng.next_u32() & 0xFF) as u8;
            let b = (rng.next_u32() & 0xFF) as u8;
            let expect = t.get(a) * t.get(b);
            let got = g.mul(a, b);
            assert!(
                (expect.is_nan() && got.is_nan()) || expect == got,
                "a={a:#x} b={b:#x}"
            );
        }
    }

    #[test]
    fn mixed_format_product_table() {
        let g = Fp8Gemm8x8::new(Fp8Format::E4M3, Fp8Format::E5M2);
        let ta = DecodeTable::new(Fp8Format::E4M3);
        let tb = DecodeTable::new(Fp8Format::E5M2);
        assert_eq!(g.mul(0x38, 0x3C), ta.get(0x38) * tb.get(0x3C));
    }
}
