//! Bit-exact software emulation of the FP8 formats implemented by the Intel
//! Gaudi 2 and Gaudi 3 accelerators (paper §2, §2.4).
//!
//! Three formats are modelled:
//!
//! * [`Fp8Format::E4M3Gaudi2`] — Gaudi 2's E4M3. Follows IEEE-754 conventions:
//!   the largest exponent is *reserved* for NaN/Inf, limiting the range to
//!   ±240 (paper §2.4).
//! * [`Fp8Format::E4M3`] — Gaudi 3 / OCP E4M3 ("fn"): the maximal exponent is
//!   available for normal numbers except mantissa=111 (NaN), extending the
//!   range to ±448 as per Micikevicius et al. (2022).
//! * [`Fp8Format::E5M2`] — IEEE-style 5-exponent format used for gradients in
//!   training; wider dynamic range, lower precision.
//!
//! The module provides:
//! * exact decode ([`decode`], [`DecodeTable`]) — every code maps to an f32
//!   exactly (all fp8 values are exactly representable in f32);
//! * round-to-nearest-even encode ([`encode_rne`]) as fast bit manipulation,
//!   exhaustively validated against a table-search oracle;
//! * stochastic-rounding encode ([`encode_stochastic`]) — unbiased cast used
//!   by Gaudi during training (paper §2.4);
//! * the hardware power-of-two rescaling trick ([`rescale_pow2`]) — adjusting
//!   the exponent bias instead of multiplying elements (paper §2.4), with the
//!   Gaudi 2 / Gaudi 3 supported scale sets in [`hw_scale_exponents`];
//! * bf16 helpers ([`bf16`]) for the high-precision side of the GEMM.

pub mod bf16_impl;
mod decode;
mod encode;
mod format;
mod stochastic;
mod tables;

pub use bf16_impl as bf16;
pub use decode::{decode, DecodeTable};
pub use encode::{encode_nearest_oracle, encode_rne, encode_rz, CastMode};
pub use format::{Fp8Format, FormatParams, SpecialCase};
pub use stochastic::encode_stochastic;
pub use tables::{decode_lut, decode_table, hw_scale_exponents, rescale_pow2, Fp8Gemm8x8};

/// A quantized FP8 value paired with its format — convenience for tests and
/// debugging; hot paths work on raw `u8` + a `Fp8Format`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fp8 {
    pub code: u8,
    pub format: Fp8Format,
}

impl Fp8 {
    pub fn from_f32(v: f32, format: Fp8Format) -> Self {
        Self {
            code: encode_rne(v, format, CastMode::SatFinite),
            format,
        }
    }

    pub fn to_f32(self) -> f32 {
        decode(self.code, self.format)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp8_wrapper_roundtrip() {
        let v = Fp8::from_f32(1.5, Fp8Format::E4M3);
        assert_eq!(v.to_f32(), 1.5);
    }
}
