//! Minimal bfloat16 support (the `half` crate is unreachable offline).
//!
//! BF16 is the high-precision type on Gaudi's GEMM path: FP8 × FP8 → FP32
//! accumulate → BF16 output (Table 1: "Two FP8 matrices are multiplied to
//! produce a BF16 output matrix"). Only conversions and a few helpers are
//! needed; arithmetic happens in f32.

/// Round-to-nearest-even f32 → bf16 bit pattern.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Quiet NaN, preserve sign.
        return ((bits >> 16) as u16) | 0x0040;
    }
    // RNE on the low 16 bits.
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x7FFF + lsb);
    (rounded >> 16) as u16
}

/// bf16 bit pattern → f32 (exact).
#[inline]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Quantize a slice to bf16 precision in place (simulating a bf16 tensor
/// stored as f32 — our tensors are f32-backed).
pub fn round_slice_to_bf16(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = bf16_to_f32(f32_to_bf16(*x));
    }
}

/// Max finite bf16 value.
pub const BF16_MAX: f32 = 3.3895314e38;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_integers_roundtrip() {
        for i in -256..=256 {
            let x = i as f32;
            assert_eq!(bf16_to_f32(f32_to_bf16(x)), x);
        }
    }

    #[test]
    fn rne_ties_to_even() {
        // 1.0 + 2^-8 is exactly between bf16(1.0) and the next bf16 value
        // (1 + 2^-7); RNE goes to even mantissa → 1.0.
        let x = 1.0 + (2.0f32).powi(-8);
        assert_eq!(bf16_to_f32(f32_to_bf16(x)), 1.0);
        // 1 + 3*2^-8 ties between 1+2^-7 (odd) and 1+2^-6 (even) → 1+2^-6.
        let x = 1.0 + 3.0 * (2.0f32).powi(-8);
        assert_eq!(bf16_to_f32(f32_to_bf16(x)), 1.0 + (2.0f32).powi(-6));
    }

    #[test]
    fn nan_and_inf() {
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::INFINITY)), f32::INFINITY);
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::NEG_INFINITY)), f32::NEG_INFINITY);
    }

    #[test]
    fn error_bounded() {
        let mut r = crate::util::rng::XorShiftRng::new(4);
        for _ in 0..10_000 {
            let x = r.normal() * 100.0;
            let y = bf16_to_f32(f32_to_bf16(x));
            let rel = if x != 0.0 { ((y - x) / x).abs() } else { 0.0 };
            assert!(rel <= (2.0f32).powi(-8), "x={x} y={y}");
        }
    }

    #[test]
    fn slice_rounding() {
        let mut v = vec![1.0f32, 1.0 + (2.0f32).powi(-9), -3.14159];
        round_slice_to_bf16(&mut v);
        assert_eq!(v[0], 1.0);
        assert_eq!(v[1], 1.0);
        assert!((v[2] + 3.140625).abs() < 2e-2);
    }
}
