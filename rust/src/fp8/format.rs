//! FP8 format descriptors (paper §2, §2.4).

/// The FP8 formats supported by the Gaudi accelerators.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Fp8Format {
    /// Gaudi 2 E4M3: IEEE-style, largest exponent reserved for NaN/Inf.
    /// Range ±240 (paper §2.4).
    E4M3Gaudi2,
    /// Gaudi 3 / OCP E4M3: maximal exponent usable for normals; only
    /// S.1111.111 is NaN; no Inf. Range ±448.
    E4M3,
    /// E5M2, IEEE-style (it is a proper subset of IEEE half precision):
    /// exp=31 reserved for Inf/NaN. Range ±57344.
    E5M2,
}

/// How a code's special bit patterns are interpreted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpecialCase {
    Normal,
    Subnormal,
    Zero,
    Inf,
    Nan,
}

/// Static parameters fully describing an FP8 format's bit layout.
#[derive(Clone, Copy, Debug)]
pub struct FormatParams {
    pub format: Fp8Format,
    /// Number of exponent bits (E).
    pub exp_bits: u32,
    /// Number of mantissa bits (M).
    pub man_bits: u32,
    /// Exponent bias.
    pub bias: i32,
    /// Whether the all-ones exponent is reserved for Inf/NaN (IEEE style).
    pub ieee_reserved_top_exp: bool,
    /// Largest finite representable magnitude.
    pub max_normal: f32,
    /// Smallest positive normal magnitude: 2^(1-bias).
    pub min_normal: f32,
    /// Smallest positive subnormal magnitude: 2^(1-bias-M).
    pub min_subnormal: f32,
    /// Canonical NaN code (positive sign).
    pub nan_code: u8,
    /// Code of the largest finite magnitude (positive sign).
    pub max_code: u8,
}

impl Fp8Format {
    pub const ALL: [Fp8Format; 3] = [Fp8Format::E4M3Gaudi2, Fp8Format::E4M3, Fp8Format::E5M2];

    pub fn params(self) -> FormatParams {
        match self {
            // E4M3 with IEEE reservation: max normal = 1.875 * 2^7 = 240.
            Fp8Format::E4M3Gaudi2 => FormatParams {
                format: self,
                exp_bits: 4,
                man_bits: 3,
                bias: 7,
                ieee_reserved_top_exp: true,
                max_normal: 240.0,
                min_normal: exp2i(-6),
                min_subnormal: exp2i(-9),
                nan_code: 0x7F, // S.1111.111 (any nonzero mantissa w/ exp=15)
                max_code: 0x77, // S.1110.111
            },
            // OCP E4M3: max normal = 1.75 * 2^8 = 448. NaN only at S.1111.111.
            Fp8Format::E4M3 => FormatParams {
                format: self,
                exp_bits: 4,
                man_bits: 3,
                bias: 7,
                ieee_reserved_top_exp: false,
                max_normal: 448.0,
                min_normal: exp2i(-6),
                min_subnormal: exp2i(-9),
                nan_code: 0x7F,
                max_code: 0x7E, // S.1111.110
            },
            // E5M2: max normal = 1.75 * 2^15 = 57344.
            Fp8Format::E5M2 => FormatParams {
                format: self,
                exp_bits: 5,
                man_bits: 2,
                bias: 15,
                ieee_reserved_top_exp: true,
                max_normal: 57344.0,
                min_normal: exp2i(-14),
                min_subnormal: exp2i(-16),
                nan_code: 0x7F, // S.11111.11 canonical
                max_code: 0x7B, // S.11110.11
            },
        }
    }

    /// `r_q` in the paper: the maximal representable quantized magnitude,
    /// used as the denominator in every scale computation (Eqs. 15, 18, 20).
    pub fn r_q(self) -> f32 {
        self.params().max_normal
    }

    /// Short name used in artifact filenames and reports.
    pub fn name(self) -> &'static str {
        match self {
            Fp8Format::E4M3Gaudi2 => "e4m3_gaudi2",
            Fp8Format::E4M3 => "e4m3",
            Fp8Format::E5M2 => "e5m2",
        }
    }

    /// Classify a code.
    pub fn classify(self, code: u8) -> SpecialCase {
        let p = self.params();
        let exp_mask = (1u8 << p.exp_bits) - 1;
        let man_mask = (1u8 << p.man_bits) - 1;
        let exp = (code >> p.man_bits) & exp_mask;
        let man = code & man_mask;
        if exp == exp_mask {
            if p.ieee_reserved_top_exp {
                return if man == 0 {
                    SpecialCase::Inf
                } else {
                    SpecialCase::Nan
                };
            }
            // OCP E4M3: only all-ones mantissa is NaN.
            if man == man_mask {
                return SpecialCase::Nan;
            }
            return SpecialCase::Normal;
        }
        if exp == 0 {
            return if man == 0 {
                SpecialCase::Zero
            } else {
                SpecialCase::Subnormal
            };
        }
        SpecialCase::Normal
    }
}

#[inline]
pub(crate) fn exp2i(e: i32) -> f32 {
    (2.0f32).powi(e)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e4m3_gaudi2_range_is_pm240() {
        let p = Fp8Format::E4M3Gaudi2.params();
        assert_eq!(p.max_normal, 240.0);
        assert_eq!(Fp8Format::E4M3Gaudi2.r_q(), 240.0);
    }

    #[test]
    fn e4m3_ocp_range_is_pm448() {
        assert_eq!(Fp8Format::E4M3.params().max_normal, 448.0);
    }

    #[test]
    fn e5m2_range() {
        assert_eq!(Fp8Format::E5M2.params().max_normal, 57344.0);
    }

    #[test]
    fn classify_specials_e4m3_gaudi2() {
        let f = Fp8Format::E4M3Gaudi2;
        assert_eq!(f.classify(0x00), SpecialCase::Zero);
        assert_eq!(f.classify(0x80), SpecialCase::Zero); // -0
        assert_eq!(f.classify(0x01), SpecialCase::Subnormal);
        assert_eq!(f.classify(0x78), SpecialCase::Inf); // exp=15, man=0
        assert_eq!(f.classify(0x79), SpecialCase::Nan);
        assert_eq!(f.classify(0x7F), SpecialCase::Nan);
        assert_eq!(f.classify(0x77), SpecialCase::Normal); // 240
    }

    #[test]
    fn classify_specials_e4m3_ocp() {
        let f = Fp8Format::E4M3;
        assert_eq!(f.classify(0x78), SpecialCase::Normal); // 256
        assert_eq!(f.classify(0x7E), SpecialCase::Normal); // 448
        assert_eq!(f.classify(0x7F), SpecialCase::Nan);
        assert_eq!(f.classify(0xFF), SpecialCase::Nan);
    }

    #[test]
    fn classify_specials_e5m2() {
        let f = Fp8Format::E5M2;
        assert_eq!(f.classify(0x7C), SpecialCase::Inf); // exp=31, man=0
        assert_eq!(f.classify(0x7D), SpecialCase::Nan);
        assert_eq!(f.classify(0x7B), SpecialCase::Normal); // 57344
        assert_eq!(f.classify(0x03), SpecialCase::Subnormal);
    }

    #[test]
    fn min_magnitudes() {
        let p = Fp8Format::E4M3.params();
        assert_eq!(p.min_normal, 0.015625); // 2^-6
        assert_eq!(p.min_subnormal, 0.001953125); // 2^-9
        let p = Fp8Format::E5M2.params();
        assert_eq!(p.min_subnormal, exp2i(-16));
    }
}
