//! f32 → FP8 cast with round-to-nearest-even (the Gaudi default cast) and
//! round-toward-zero.
//!
//! Two implementations exist:
//! * [`encode_rne`] — branch-light bit manipulation, the hot path;
//! * [`encode_nearest_oracle`] — a table search that is correct *by
//!   definition* (nearest representable, ties to the even mantissa code).
//!
//! `encode_rne` is validated against the oracle exhaustively over every code
//! midpoint and by property tests over millions of random floats (see tests
//! and `rust/tests/fp8_exhaustive.rs`).

use super::decode::DecodeTable;
use super::format::{exp2i, Fp8Format};

/// Behavior on overflow (|x| beyond the largest finite value).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CastMode {
    /// Saturate to the largest finite magnitude (Gaudi inference cast; the
    /// paper §1: "large absolute values are clipped to the maximum").
    SatFinite,
    /// IEEE-style: overflow produces Inf (formats with Inf) or NaN (OCP
    /// E4M3, which has no Inf).
    Ieee,
}

#[inline]
fn overflow_code(sign: u8, format: Fp8Format, mode: CastMode) -> u8 {
    let p = format.params();
    match mode {
        CastMode::SatFinite => sign | p.max_code,
        CastMode::Ieee => {
            if p.ieee_reserved_top_exp {
                // Inf: top exponent, zero mantissa.
                sign | (((1u8 << p.exp_bits) - 1) << p.man_bits)
            } else {
                sign | p.nan_code
            }
        }
    }
}

/// Round-to-nearest-even cast, bit-manipulation implementation.
pub fn encode_rne(x: f32, format: Fp8Format, mode: CastMode) -> u8 {
    let p = format.params();
    let bits = x.to_bits();
    let sign = ((bits >> 31) as u8) << 7;
    let abs_bits = bits & 0x7FFF_FFFF;

    if abs_bits > 0x7F80_0000 {
        return sign | p.nan_code; // NaN propagates
    }
    if abs_bits == 0x7F80_0000 {
        return overflow_code(sign, format, mode); // Inf input
    }
    if abs_bits == 0 {
        return sign; // ±0
    }

    let m = p.man_bits;
    let min_norm_exp = 1 - p.bias;
    let e_unb = ((abs_bits >> 23) as i32) - 127;

    if e_unb < min_norm_exp {
        // Subnormal target (possibly rounding up into the minimal normal).
        // q = RNE(x / ulp_sub), ulp_sub = 2^(min_norm_exp - m).
        let x_abs = f32::from_bits(abs_bits);
        let q = (x_abs * exp2i(m as i32 - min_norm_exp)).round_ties_even() as u32;
        // q ∈ [0, 2^m]; q == 2^m lands exactly on the minimal normal whose
        // code is (1 << m) — the expression below covers it uniformly.
        return sign | q as u8;
    }

    // Normal path: RNE on the f32 mantissa via the classic add-half trick;
    // a carry out of the mantissa correctly bumps the exponent.
    let shift = 23 - m;
    let lsb = (abs_bits >> shift) & 1;
    let rounded = abs_bits + ((1u32 << (shift - 1)) - 1) + lsb;
    let r_exp = ((rounded >> 23) & 0xFF) as i32 - 127;
    let r_man = ((rounded >> shift) & ((1u32 << m) - 1)) as u8;

    // Overflow detection against the format's top finite value.
    let (max_exp, max_man) = {
        let pmax = p.max_code;
        (
            (((pmax >> m) & ((1 << p.exp_bits) - 1)) as i32) - p.bias,
            pmax & ((1 << m) - 1),
        )
    };
    if r_exp > max_exp || (r_exp == max_exp && r_man > max_man) {
        return overflow_code(sign, format, mode);
    }
    let code_exp = (r_exp + p.bias) as u8;
    sign | (code_exp << m) | r_man
}

/// Round-toward-zero cast (truncation). Not used on Gaudi's GEMM path but
/// included for completeness and as a reference point in rounding studies.
pub fn encode_rz(x: f32, format: Fp8Format, mode: CastMode) -> u8 {
    let p = format.params();
    let bits = x.to_bits();
    let sign = ((bits >> 31) as u8) << 7;
    let abs_bits = bits & 0x7FFF_FFFF;
    if abs_bits > 0x7F80_0000 {
        return sign | p.nan_code;
    }
    if abs_bits == 0x7F80_0000 {
        return overflow_code(sign, format, mode);
    }
    if abs_bits == 0 {
        return sign;
    }
    let m = p.man_bits;
    let min_norm_exp = 1 - p.bias;
    let e_unb = ((abs_bits >> 23) as i32) - 127;
    let x_abs = f32::from_bits(abs_bits);
    if x_abs > p.max_normal {
        // RZ of an overflow saturates to max in both modes (truncation never
        // reaches Inf).
        return sign | p.max_code;
    }
    if e_unb < min_norm_exp {
        let q = (x_abs * exp2i(m as i32 - min_norm_exp)).floor() as u32;
        return sign | q as u8;
    }
    let shift = 23 - m;
    let r_exp = e_unb;
    let r_man = ((abs_bits >> shift) & ((1u32 << m) - 1)) as u8;
    let code_exp = (r_exp + p.bias) as u8;
    sign | (code_exp << m) | r_man
}

/// Correct-by-definition nearest encode: searches the decode table for the
/// closest representable value; ties go to the even mantissa code (even code
/// parity ≡ even mantissa LSB, including across binade boundaries).
pub fn encode_nearest_oracle(x: f32, table: &DecodeTable, mode: CastMode) -> u8 {
    let p = table.format.params();
    if x.is_nan() {
        return p.nan_code | if x.is_sign_negative() { 0x80 } else { 0 };
    }
    let sign = if x.is_sign_negative() { 0x80u8 } else { 0 };
    let ax = x.abs();
    if ax.is_infinite() {
        return overflow_code(sign, table.format, mode);
    }
    let sp = table.sorted_positive();
    // lint:allow(no-unwrap-in-lib): sorted_positive() is non-empty for every FP8 format (each has finite positive codes)
    let max_val = sp.last().unwrap().0;
    if ax > max_val {
        // Nearest finite is max; in IEEE mode values beyond the RNE
        // threshold overflow to Inf/NaN. The spacing above max equals the
        // spacing below it (max sits mid-binade in all three formats:
        // its mantissa field is not zero), and the exact midpoint ties to
        // the even mantissa: up (overflow) iff max_code's mantissa is odd.
        let second = sp[sp.len() - 2].0;
        let ulp_above = max_val - second;
        let half = ulp_above / 2.0;
        let tie_up = p.max_code & 1 == 1;
        let over = ax - max_val > half || (ax - max_val == half && tie_up);
        if over && mode == CastMode::Ieee {
            return overflow_code(sign, table.format, mode);
        }
        return sign | p.max_code;
    }
    // Binary search for the insertion point.
    let idx = sp.partition_point(|(v, _)| *v < ax);
    let candidates = [
        idx.checked_sub(1).map(|i| sp[i]),
        sp.get(idx).copied(),
    ];
    let mut best: Option<(f32, u8)> = None;
    for c in candidates.into_iter().flatten() {
        best = Some(match best {
            None => c,
            Some(b) => {
                let (db, dc) = ((b.0 - ax).abs(), (c.0 - ax).abs());
                if dc < db {
                    c
                } else if dc > db {
                    b
                } else {
                    // exact tie → even code (mantissa LSB 0)
                    if c.1 & 1 == 0 {
                        c
                    } else {
                        b
                    }
                }
            }
        });
    }
    // lint:allow(no-unwrap-in-lib): candidates always yields at least one code — idx==0 implies sp[0] exists, idx==len implies sp[len-1] exists
    sign | best.unwrap().1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall_msg, interesting_f32};

    fn codes_equal_semantically(a: u8, b: u8, f: Fp8Format) -> bool {
        use crate::fp8::decode::decode;
        let (va, vb) = (decode(a, f), decode(b, f));
        (va.is_nan() && vb.is_nan()) || (va == vb && (va != 0.0 || (a & 0x80) == (b & 0x80)))
    }

    #[test]
    fn roundtrip_every_finite_code() {
        // encode(decode(c)) must reproduce c for every finite code.
        for f in Fp8Format::ALL {
            let t = DecodeTable::new(f);
            for c in 0u16..=255 {
                let c = c as u8;
                let v = t.get(c);
                if !v.is_finite() {
                    continue;
                }
                let e = encode_rne(v, f, CastMode::SatFinite);
                assert!(
                    codes_equal_semantically(e, c, f),
                    "format {f:?}: code {c:#04x} (value {v}) re-encoded to {e:#04x}"
                );
            }
        }
    }

    #[test]
    fn midpoints_round_to_even_exhaustive() {
        // For every adjacent pair of positive representable values, the exact
        // midpoint must round to the code with even parity.
        for f in Fp8Format::ALL {
            let t = DecodeTable::new(f);
            let sp = t.sorted_positive();
            for w in sp.windows(2) {
                let (lo, hi) = (w[0], w[1]);
                if lo.0 == hi.0 {
                    continue;
                }
                let mid = lo.0 + (hi.0 - lo.0) / 2.0;
                // Midpoints of fp8 neighbours are exact in f32.
                let e = encode_rne(mid, f, CastMode::SatFinite);
                let expect = if hi.1 & 1 == 0 { hi.1 } else { lo.1 };
                assert_eq!(
                    e, expect,
                    "format {f:?}: midpoint {mid} between {} ({:#04x}) and {} ({:#04x}) → {e:#04x}",
                    lo.0, lo.1, hi.0, hi.1
                );
            }
        }
    }

    #[test]
    fn bitmanip_matches_oracle_on_interesting_floats() {
        for f in Fp8Format::ALL {
            let t = DecodeTable::new(f);
            let scale = f.params().max_normal / 4.0;
            forall_msg(
                0xF8_u64 + f as u64,
                20_000,
                |r| interesting_f32(r, scale),
                |x| {
                    for mode in [CastMode::SatFinite, CastMode::Ieee] {
                        let fast = encode_rne(*x, f, mode);
                        let slow = encode_nearest_oracle(*x, &t, mode);
                        if !codes_equal_semantically(fast, slow, f) {
                            return Err(format!(
                                "format {f:?} mode {mode:?} x={x}: fast={fast:#04x} slow={slow:#04x}"
                            ));
                        }
                    }
                    Ok(())
                },
            );
        }
    }

    #[test]
    fn saturation_vs_ieee_overflow() {
        // Above threshold: SatFinite clamps, Ieee produces Inf (or NaN for OCP).
        let cases = [
            (Fp8Format::E4M3Gaudi2, 10_000.0f32),
            (Fp8Format::E4M3, 10_000.0),
            (Fp8Format::E5M2, 1e6),
        ];
        for (f, big) in cases {
            let p = f.params();
            let sat = encode_rne(big, f, CastMode::SatFinite);
            assert_eq!(sat, p.max_code, "{f:?}");
            assert_eq!(crate::fp8::decode(sat, f), p.max_normal);
            let ieee = encode_rne(big, f, CastMode::Ieee);
            let v = crate::fp8::decode(ieee, f);
            assert!(v.is_infinite() || v.is_nan(), "{f:?} → {v}");
            // Negative side.
            let nsat = encode_rne(-big, f, CastMode::SatFinite);
            assert_eq!(crate::fp8::decode(nsat, f), -p.max_normal);
        }
    }

    #[test]
    fn gaudi2_saturates_at_240_not_448() {
        // The paper's headline format difference (§2.4).
        let x = 300.0f32;
        let g2 = encode_rne(x, Fp8Format::E4M3Gaudi2, CastMode::SatFinite);
        let g3 = encode_rne(x, Fp8Format::E4M3, CastMode::SatFinite);
        assert_eq!(crate::fp8::decode(g2, Fp8Format::E4M3Gaudi2), 240.0);
        assert_eq!(crate::fp8::decode(g3, Fp8Format::E4M3), 288.0); // 1.125*256
    }

    #[test]
    fn underflow_to_zero_and_subnormals() {
        for f in Fp8Format::ALL {
            let p = f.params();
            // Below half the min subnormal → 0.
            let tiny = p.min_subnormal / 4.0;
            assert_eq!(encode_rne(tiny, f, CastMode::SatFinite), 0);
            assert_eq!(encode_rne(-tiny, f, CastMode::SatFinite), 0x80);
            // Exactly min subnormal roundtrips.
            let c = encode_rne(p.min_subnormal, f, CastMode::SatFinite);
            assert_eq!(crate::fp8::decode(c, f), p.min_subnormal);
            // Half the min subnormal is a tie → even → 0.
            let c = encode_rne(p.min_subnormal / 2.0, f, CastMode::SatFinite);
            assert_eq!(crate::fp8::decode(c, f), 0.0);
            // 0.75 * min_subnormal → nearest is min_subnormal.
            let c = encode_rne(p.min_subnormal * 0.75, f, CastMode::SatFinite);
            assert_eq!(crate::fp8::decode(c, f), p.min_subnormal);
        }
    }

    #[test]
    fn nan_propagates() {
        for f in Fp8Format::ALL {
            let c = encode_rne(f32::NAN, f, CastMode::SatFinite);
            assert!(crate::fp8::decode(c, f).is_nan());
        }
    }

    #[test]
    fn rz_truncates() {
        let f = Fp8Format::E4M3;
        // 1.9 truncates to 1.875 (1.111), RNE would give 1.875 too; use 1.96:
        // grid around 2.0: 1.875, 2.0. RZ(1.99) = 1.875, RNE(1.99) = 2.0.
        assert_eq!(crate::fp8::decode(encode_rz(1.99, f, CastMode::SatFinite), f), 1.875);
        assert_eq!(crate::fp8::decode(encode_rne(1.99, f, CastMode::SatFinite), f), 2.0);
        // RZ never overflows to Inf.
        assert_eq!(
            crate::fp8::decode(encode_rz(1e30, f, CastMode::Ieee), f),
            448.0
        );
    }

    #[test]
    fn rz_magnitude_never_exceeds_input() {
        for f in Fp8Format::ALL {
            let t = DecodeTable::new(f);
            crate::util::prop::forall(
                0xA11CE,
                10_000,
                |r| interesting_f32(r, f.params().max_normal / 2.0),
                |x| {
                    let v = t.get(encode_rz(*x, f, CastMode::SatFinite));
                    v.abs() <= x.abs() && (v == 0.0 || v.signum() == x.signum())
                },
            );
        }
    }

    #[test]
    fn rne_error_bounded_by_half_ulp() {
        // |encode(x) - x| ≤ max(ulp(x)/2) for in-range x — the fundamental
        // quantization-error bound used throughout the paper's analysis.
        for f in Fp8Format::ALL {
            let p = f.params();
            let t = DecodeTable::new(f);
            crate::util::prop::forall_msg(
                0xBEEF,
                10_000,
                |r| r.range_f32(-p.max_normal, p.max_normal),
                |x| {
                    let v = t.get(encode_rne(*x, f, CastMode::SatFinite));
                    let ulp = (x.abs().max(p.min_normal)) * exp2i(-(p.man_bits as i32));
                    if (v - x).abs() <= ulp / 2.0 + 1e-12 {
                        Ok(())
                    } else {
                        Err(format!("x={x} v={v} ulp={ulp}"))
                    }
                },
            );
        }
    }
}
