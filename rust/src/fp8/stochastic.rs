//! Stochastic-rounding cast (paper §2.4).
//!
//! During the high-precision → FP8 cast, Gaudi can apply stochastic rounding:
//! the value rounds up with probability proportional to its distance from the
//! lower grid point, making the cast *unbiased* (E[Q(x)] = x for in-range x).
//! The paper notes the overhead is negligible versus RNE, that it is
//! beneficial for training, and that it is *not* applied in the accumulator
//! (which stays high-precision).

use super::encode::{encode_rz, CastMode};
use super::format::{exp2i, Fp8Format};
use crate::util::rng::XorShiftRng;

/// Stochastic-rounding encode. Deterministic given the RNG state.
///
/// Implementation: find the lower neighbour by truncation (RZ on magnitude),
/// compute the fractional position within the ulp, and round up with that
/// probability using a 24-bit uniform draw.
pub fn encode_stochastic(
    x: f32,
    format: Fp8Format,
    mode: CastMode,
    rng: &mut XorShiftRng,
) -> u8 {
    let p = format.params();
    if x.is_nan() {
        return p.nan_code | if x.is_sign_negative() { 0x80 } else { 0 };
    }
    let sign = if x.is_sign_negative() { 0x80u8 } else { 0 };
    let ax = x.abs();
    if ax >= p.max_normal {
        // Overflow: stochastic rounding still saturates on the inference
        // cast. (Between max_normal and max_normal+ulp the probabilistic
        // round-up has nowhere to go in SatFinite mode.)
        return match mode {
            CastMode::SatFinite => sign | p.max_code,
            CastMode::Ieee => {
                if ax == p.max_normal {
                    sign | p.max_code
                } else {
                    super::encode::encode_rne(x, format, mode)
                }
            }
        };
    }
    // Lower grid point via truncation of the magnitude.
    let lo_code = encode_rz(ax, format, CastMode::SatFinite);
    let lo = super::decode::decode(lo_code, format);
    debug_assert!(lo <= ax);
    if lo == ax {
        return sign | lo_code;
    }
    // ulp at lo: spacing to the next representable magnitude.
    let m = p.man_bits as i32;
    let ulp = if ax < p.min_normal {
        p.min_subnormal
    } else {
        // lo is normal; ulp = 2^(floor(log2 lo) - m). Use lo's exponent.
        let e = lo.log2().floor() as i32;
        exp2i(e - m)
    };
    let frac = ((ax - lo) / ulp).clamp(0.0, 1.0);
    let draw = (rng.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32);
    let round_up = draw < frac;
    if round_up {
        // Next code up in magnitude is lo_code + 1 (positive codes are
        // value-ordered; +1 crosses binade boundaries correctly).
        let up = lo_code + 1;
        // Guard: never step into Inf/NaN space.
        if super::decode::decode(up, format).is_finite() {
            return sign | up;
        }
        return sign | p.max_code;
    }
    sign | lo_code
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::decode::decode;

    #[test]
    fn exact_values_never_randomized() {
        let mut rng = XorShiftRng::new(1);
        for f in Fp8Format::ALL {
            for code in [0x00u8, 0x38, 0x3C, 0x01, 0x08] {
                let v = decode(code, f);
                if !v.is_finite() {
                    continue;
                }
                for _ in 0..32 {
                    let c = encode_stochastic(v, f, CastMode::SatFinite, &mut rng);
                    assert_eq!(decode(c, f), v, "format {f:?} code {code:#x}");
                }
            }
        }
    }

    #[test]
    fn mean_is_unbiased() {
        // E[Q(x)] ≈ x: the defining property (paper: "unbiased rounding
        // method introduces increased quantization noise").
        let f = Fp8Format::E4M3;
        let mut rng = XorShiftRng::new(7);
        for &x in &[1.3f32, 0.071, 100.0, 3.99, 0.0021] {
            let n = 40_000;
            let mut sum = 0.0f64;
            for _ in 0..n {
                let c = encode_stochastic(x, f, CastMode::SatFinite, &mut rng);
                sum += decode(c, f) as f64;
            }
            let mean = sum / n as f64;
            let rel = ((mean - x as f64) / x as f64).abs();
            assert!(rel < 0.01, "x={x}: mean={mean} rel={rel}");
        }
    }

    #[test]
    fn rne_is_biased_where_sr_is_not() {
        // For a value 1/4 of the way between grid points, RNE always returns
        // the lower point (bias = -0.25 ulp); SR returns the upper point 25%
        // of the time (bias ~ 0).
        let f = Fp8Format::E4M3;
        let lo = 1.0f32;
        let hi = 1.125f32;
        let x = lo + 0.25 * (hi - lo);
        let rne = decode(super::super::encode::encode_rne(x, f, CastMode::SatFinite), f);
        assert_eq!(rne, lo);
        let mut rng = XorShiftRng::new(9);
        let n = 20_000;
        let ups = (0..n)
            .filter(|_| decode(encode_stochastic(x, f, CastMode::SatFinite, &mut rng), f) == hi)
            .count();
        let frac = ups as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn results_are_always_neighbours() {
        let mut rng = XorShiftRng::new(3);
        for f in Fp8Format::ALL {
            let p = f.params();
            crate::util::prop::forall_msg(
                0x51,
                5_000,
                |r| r.range_f32(-p.max_normal * 0.99, p.max_normal * 0.99),
                |x| {
                    let c = encode_stochastic(*x, f, CastMode::SatFinite, &mut rng);
                    let v = decode(c, f);
                    if !v.is_finite() {
                        return Err(format!("non-finite {v}"));
                    }
                    // v must be within one ulp of x.
                    let ulp = (x.abs().max(p.min_normal)) * exp2i(-(p.man_bits as i32));
                    if (v - x).abs() <= ulp + 1e-12 {
                        Ok(())
                    } else {
                        Err(format!("x={x} v={v} ulp={ulp}"))
                    }
                },
            );
        }
    }

    #[test]
    fn saturates_on_overflow() {
        let mut rng = XorShiftRng::new(5);
        let f = Fp8Format::E4M3Gaudi2;
        let c = encode_stochastic(1e6, f, CastMode::SatFinite, &mut rng);
        assert_eq!(decode(c, f), 240.0);
        let c = encode_stochastic(-1e6, f, CastMode::SatFinite, &mut rng);
        assert_eq!(decode(c, f), -240.0);
    }
}
