//! Exact FP8 → f32 decode. Every finite FP8 value is exactly representable
//! in f32, so decode is lossless by construction.

use super::format::{exp2i, Fp8Format, SpecialCase};

/// Decode one code to f32. Inf maps to f32 INFINITY (E4M3-Gaudi2 / E5M2),
/// NaN to f32 NAN. Sign of zero is preserved.
pub fn decode(code: u8, format: Fp8Format) -> f32 {
    let p = format.params();
    let sign = if code & 0x80 != 0 { -1.0f32 } else { 1.0 };
    match format.classify(code) {
        SpecialCase::Nan => f32::NAN,
        SpecialCase::Inf => sign * f32::INFINITY,
        SpecialCase::Zero => sign * 0.0,
        SpecialCase::Subnormal => {
            let man = (code & ((1 << p.man_bits) - 1)) as f32;
            sign * man * exp2i(1 - p.bias - p.man_bits as i32)
        }
        SpecialCase::Normal => {
            let exp = ((code >> p.man_bits) & ((1 << p.exp_bits) - 1)) as i32;
            let man = (code & ((1 << p.man_bits) - 1)) as f32;
            let frac = 1.0 + man * exp2i(-(p.man_bits as i32));
            sign * frac * exp2i(exp - p.bias)
        }
    }
}

/// Precomputed 256-entry decode table — the hot-path decode used by the
/// emulated GEMM. NaN entries hold f32::NAN; callers on the GEMM path are
/// expected to have saturating-cast inputs so specials never occur there.
#[derive(Clone)]
pub struct DecodeTable {
    pub format: Fp8Format,
    pub values: [f32; 256],
}

impl DecodeTable {
    pub fn new(format: Fp8Format) -> Self {
        let mut values = [0.0f32; 256];
        for (c, v) in values.iter_mut().enumerate() {
            *v = decode(c as u8, format);
        }
        Self { format, values }
    }

    #[inline]
    pub fn get(&self, code: u8) -> f32 {
        self.values[code as usize]
    }

    /// Sorted list of (value, code) for all finite non-negative codes —
    /// the encode oracle searches this.
    pub fn sorted_positive(&self) -> Vec<(f32, u8)> {
        let mut v: Vec<(f32, u8)> = (0u16..=255)
            .map(|c| (self.values[c as usize], c as u8))
            .filter(|(v, c)| v.is_finite() && c & 0x80 == 0)
            .collect();
        v.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_known_values_e4m3() {
        let f = Fp8Format::E4M3;
        // 0x38 = 0.0111.000 → exp=7-7=0 → 1.0
        assert_eq!(decode(0x38, f), 1.0);
        // 0x3C = 0.0111.100 → 1.5
        assert_eq!(decode(0x3C, f), 1.5);
        // 0xBC → -1.5
        assert_eq!(decode(0xBC, f), -1.5);
        // max normal 0x7E → 448
        assert_eq!(decode(0x7E, f), 448.0);
        // min subnormal 0x01 → 2^-9
        assert_eq!(decode(0x01, f), exp2i(-9));
        // min normal 0x08 → 2^-6
        assert_eq!(decode(0x08, f), exp2i(-6));
    }

    #[test]
    fn decode_known_values_e4m3_gaudi2() {
        let f = Fp8Format::E4M3Gaudi2;
        assert_eq!(decode(0x77, f), 240.0); // max normal
        assert!(decode(0x78, f).is_infinite());
        assert!(decode(0x79, f).is_nan());
        assert_eq!(decode(0x38, f), 1.0);
    }

    #[test]
    fn decode_known_values_e5m2() {
        let f = Fp8Format::E5M2;
        // 0x3C = 0.01111.00 → exp=15-15=0 → 1.0
        assert_eq!(decode(0x3C, f), 1.0);
        assert_eq!(decode(0x7B, f), 57344.0);
        assert!(decode(0x7C, f).is_infinite());
        assert!(decode(0x7D, f).is_nan());
        assert_eq!(decode(0x01, f), exp2i(-16));
    }

    #[test]
    fn negative_zero_preserved() {
        for f in Fp8Format::ALL {
            let v = decode(0x80, f);
            assert_eq!(v, 0.0);
            assert!(v.is_sign_negative());
        }
    }

    #[test]
    fn table_matches_scalar_decode() {
        for f in Fp8Format::ALL {
            let t = DecodeTable::new(f);
            for c in 0u16..=255 {
                let a = t.get(c as u8);
                let b = decode(c as u8, f);
                assert!(
                    (a.is_nan() && b.is_nan()) || a == b,
                    "format {f:?} code {c:#x}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn decode_is_monotone_on_positive_codes() {
        // Within positive finite codes, numeric value increases with code.
        for f in Fp8Format::ALL {
            let t = DecodeTable::new(f);
            let sp = t.sorted_positive();
            for w in sp.windows(2) {
                // Strictly increasing except the two zeros (+0 appears once).
                assert!(w[0].0 < w[1].0 || (w[0].0 == 0.0 && w[1].0 == 0.0));
            }
            // And sorted order equals code order for positives.
            let codes: Vec<u8> = sp.iter().map(|(_, c)| *c).collect();
            let mut sorted_codes = codes.clone();
            sorted_codes.sort();
            assert_eq!(codes, sorted_codes, "format {f:?}");
        }
    }

    #[test]
    fn e4m3_variants_agree_below_240() {
        let g2 = DecodeTable::new(Fp8Format::E4M3Gaudi2);
        let g3 = DecodeTable::new(Fp8Format::E4M3);
        for c in 0u16..=255 {
            let c = c as u8;
            let (a, b) = (g2.get(c), g3.get(c));
            if a.is_finite() && a.abs() <= 240.0 {
                assert_eq!(a, b, "code {c:#x}");
            }
        }
    }

    #[test]
    fn all_finite_codes_counted() {
        // E4M3 OCP: 256 codes - 2 NaN = 254 finite (incl. two zeros).
        let t = DecodeTable::new(Fp8Format::E4M3);
        let finite = (0u16..=255)
            .filter(|c| t.get(*c as u8).is_finite())
            .count();
        assert_eq!(finite, 254);
        // E4M3 Gaudi2: 2 Inf + 14 NaN removed → 240 finite.
        let t = DecodeTable::new(Fp8Format::E4M3Gaudi2);
        let finite = (0u16..=255)
            .filter(|c| t.get(*c as u8).is_finite())
            .count();
        assert_eq!(finite, 240);
        // E5M2: exp=31 (8 codes) are Inf/NaN → 248 finite.
        let t = DecodeTable::new(Fp8Format::E5M2);
        let finite = (0u16..=255)
            .filter(|c| t.get(*c as u8).is_finite())
            .count();
        assert_eq!(finite, 248);
    }
}
