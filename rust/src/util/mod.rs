//! Dependency-free utilities: deterministic RNG, a small property-testing
//! helper (stand-in for `proptest`, which is unreachable in this offline
//! environment), a micro-benchmark harness (stand-in for `criterion`), and a
//! minimal JSON emitter for experiment records. `pool` adds a scoped
//! worker pool (stand-in for `rayon`) driving the data-parallel paged
//! attention read path.

pub mod bench;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;

pub use bench::Bencher;
pub use pool::Parallelism;
pub use rng::XorShiftRng;

/// Format a float with engineering-style precision used across report tables.
pub fn fmt_f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

/// Render a monospace table (markdown-ish) from a header and rows.
/// Used by every table/figure regenerator so output is uniform.
pub fn render_table(title: &str, header: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    out.push_str(&format!("## {title}\n"));
    let line = |cells: &[String], widths: &[usize]| -> String {
        let mut s = String::from("|");
        for (c, w) in cells.iter().zip(widths) {
            s.push_str(&format!(" {c:<w$} |"));
        }
        s.push('\n');
        s
    };
    out.push_str(&line(
        &header.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        &widths,
    ));
    let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
    out.push_str(&line(&sep, &widths));
    for row in rows {
        out.push_str(&line(row, &widths));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let t = render_table(
            "T",
            &["a", "bbbb"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
        assert!(t.contains("## T"));
        assert!(t.contains("| a   | bbbb |"));
        assert!(t.contains("| 333 | 4    |"));
    }

    #[test]
    fn fmt_f_precision() {
        assert_eq!(fmt_f(1.23456, 2), "1.23");
    }
}
