//! Micro-benchmark harness (offline stand-in for `criterion`).
//!
//! Each `[[bench]]` target constructs a [`Bencher`], registers closures, and
//! prints a fixed-format report: warmup, then `samples` timed runs, reporting
//! median / p10 / p90 and derived throughput. Deliberately simple and
//! deterministic in structure so `cargo bench` output is diffable.

// lint:allow(clock-discipline): the bench harness measures real elapsed time by design — an obs::Clock indirection here would only obscure what is being timed
use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn median_ns_per_iter(&self) -> f64 {
        self.median.as_nanos() as f64 / self.iters_per_sample as f64
    }
}

pub struct Bencher {
    pub warmup: Duration,
    pub target_sample: Duration,
    pub samples: usize,
    results: Vec<BenchResult>,
    group: String,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new("bench")
    }
}

impl Bencher {
    pub fn new(group: &str) -> Self {
        // Allow a fast smoke mode for CI: BENCH_FAST=1 shrinks durations.
        let fast = std::env::var("BENCH_FAST").is_ok();
        Self {
            warmup: if fast {
                Duration::from_millis(20)
            } else {
                Duration::from_millis(200)
            },
            target_sample: if fast {
                Duration::from_millis(20)
            } else {
                Duration::from_millis(100)
            },
            samples: if fast { 5 } else { 15 },
            results: Vec::new(),
            group: group.to_string(),
        }
    }

    /// Benchmark `f`, which performs ONE logical iteration per call.
    /// `work` is a human-readable unit count per iteration (e.g. FLOPs or
    /// elements) used to derive throughput.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + calibration: how many iters fit in target_sample?
        // lint:allow(clock-discipline): wall time is the measurement itself
        let start = Instant::now();
        let mut calib_iters: u64 = 0;
        while start.elapsed() < self.warmup {
            f();
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / calib_iters.max(1) as f64;
        let iters = ((self.target_sample.as_secs_f64() / per_iter).ceil() as u64).max(1);

        let mut times: Vec<Duration> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            // lint:allow(clock-discipline): wall time is the measurement itself
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            times.push(t0.elapsed());
        }
        times.sort();
        let res = BenchResult {
            name: format!("{}/{}", self.group, name),
            median: times[times.len() / 2],
            p10: times[times.len() / 10],
            p90: times[times.len() * 9 / 10],
            iters_per_sample: iters,
        };
        println!(
            "{:<52} {:>12.1} ns/iter  (p10 {:>10.1}, p90 {:>10.1}, {} iters/sample)",
            res.name,
            res.median_ns_per_iter(),
            res.p10.as_nanos() as f64 / iters as f64,
            res.p90.as_nanos() as f64 / iters as f64,
            iters
        );
        self.results.push(res);
        let i = self.results.len() - 1;
        &self.results[i]
    }

    /// Benchmark and report throughput in `unit` (e.g. "GFLOP/s") where one
    /// iteration performs `work_per_iter` units.
    pub fn bench_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        work_per_iter: f64,
        unit: &str,
        f: F,
    ) {
        let r = self.bench(name, f);
        let per_sec = work_per_iter / (r.median_ns_per_iter() * 1e-9);
        println!(
            "{:<52} {:>12.3} {unit}",
            format!("{}  [throughput]", r.name),
            per_sec / 1e9
        );
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// `std::hint::black_box` re-export so bench targets don't import std paths.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        std::env::set_var("BENCH_FAST", "1");
        let mut b = Bencher::new("t");
        let mut acc = 0u64;
        b.bench("noop", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].median_ns_per_iter() >= 0.0);
    }
}
