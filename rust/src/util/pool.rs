//! Dependency-free scoped worker pool for data-parallel hot paths.
//!
//! The paged attention read path splits an attend batch into independent
//! per-(slot, layer, kv-head) online-softmax tile tasks; this module runs
//! such task batches across `std::thread::scope` workers with zero
//! dependencies and zero allocation inside the runners themselves. Two
//! invariants make the result deterministic regardless of worker count:
//!
//! 1. tasks are split into **contiguous** index chunks — chunk `i` of `w`
//!    is exactly `[i*n/w, (i+1)*n/w)` — so which worker executes a task
//!    never changes *which* task writes *which* output row;
//! 2. every task owns a disjoint output region, and per-task work reduces
//!    internally in a fixed order (the caller's kernel), so no
//!    cross-worker reduction order exists to vary.
//!
//! Worker count comes from [`Parallelism`]: an explicit count, sequential,
//! or auto-detection via the `REPRO_NUM_THREADS` environment knob (the
//! `RAYON_NUM_THREADS` convention) falling back to
//! `std::thread::available_parallelism`.

use std::ops::Range;
use std::sync::OnceLock;

/// Environment variable naming the worker count for `Parallelism::Auto`.
pub const WORKERS_ENV: &str = "REPRO_NUM_THREADS";

/// Worker-count policy for data-parallel sections.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Parallelism {
    /// Run inline on the calling thread (exactly one worker).
    Sequential,
    /// Exactly `n` workers (clamped to at least 1).
    Fixed(usize),
    /// `REPRO_NUM_THREADS` if set and valid, else the machine's available
    /// parallelism. Detected once per process and cached.
    #[default]
    Auto,
}

impl Parallelism {
    /// Resolve the policy to a concrete worker count (always ≥ 1).
    pub fn workers(self) -> usize {
        match self {
            Parallelism::Sequential => 1,
            Parallelism::Fixed(n) => n.max(1),
            Parallelism::Auto => auto_workers(),
        }
    }
}

fn parse_workers(s: &str) -> Option<usize> {
    match s.trim().parse::<usize>() {
        Ok(n) if n >= 1 => Some(n),
        _ => None,
    }
}

/// Auto-detected worker count: `REPRO_NUM_THREADS` (if set to a positive
/// integer) else `std::thread::available_parallelism`. Read once per
/// process — later environment changes are not observed, matching the
/// rayon convention.
pub fn auto_workers() -> usize {
    static DETECTED: OnceLock<usize> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        std::env::var(WORKERS_ENV)
            .ok()
            .and_then(|s| parse_workers(&s))
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
            })
    })
}

/// Contiguous chunk `i` of `0..n` split `w` ways: `[i*n/w, (i+1)*n/w)`.
/// Chunks tile `0..n` exactly, differ in size by at most one, and every
/// chunk is non-empty when `w <= n`.
#[inline]
pub fn chunk_range(n: usize, w: usize, i: usize) -> Range<usize> {
    (i * n / w)..((i + 1) * n / w)
}

/// Run `n` independent fixed-stride tasks across scoped workers.
///
/// Task `t` owns output rows `out[t*stride..(t+1)*stride]`. The task range
/// is split into `min(states.len(), n)` contiguous chunks; each worker
/// gets one `&mut S` scratch slot from `states` and the sub-slice of `out`
/// covering exactly its chunk's rows, then `f(state, out_chunk, range)`
/// must process every task in `range`, writing task `t` at
/// `out_chunk[(t - range.start) * stride..]`. With one worker (or one
/// task) everything runs inline on the calling thread — no threads spawn.
///
/// Deterministic by construction: chunk boundaries depend only on
/// `(n, worker count)` and workers share no mutable state.
// lint: hot-path
pub fn run_partitioned<S, T, F>(states: &mut [S], out: &mut [T], n: usize, stride: usize, f: F)
where
    S: Send,
    T: Send,
    F: Fn(&mut S, &mut [T], Range<usize>) + Sync,
{
    assert!(!states.is_empty(), "run_partitioned needs >= 1 worker state");
    assert_eq!(out.len(), n * stride, "out must hold n stride-wide rows");
    let w = states.len().min(n.max(1));
    if w <= 1 {
        f(&mut states[0], out, 0..n);
        return;
    }
    std::thread::scope(|scope| {
        let mut states = &mut states[..w];
        let mut out = out;
        for i in 0..w {
            let r = chunk_range(n, w, i);
            // lint:allow(no-unwrap-in-lib): i < w <= states.len(), split cannot fail
            let (st, srest) = std::mem::take(&mut states).split_first_mut().expect("state");
            states = srest;
            let (o, orest) = std::mem::take(&mut out).split_at_mut((r.end - r.start) * stride);
            out = orest;
            if i == w - 1 {
                // The caller's thread takes the last chunk instead of idling.
                f(st, o, r);
            } else {
                let fr = &f;
                scope.spawn(move || fr(st, o, r));
            }
        }
    });
}

/// Run one pre-built job per scoped worker. The caller partitions its
/// data into `jobs` (each owning disjoint `&mut` regions); the last job
/// runs on the calling thread. For irregular partitions — e.g. exporting
/// a sorted block-id list whose per-chunk byte spans differ — where
/// [`run_partitioned`]'s uniform stride does not apply.
// lint: hot-path
pub fn run_scoped<J, F>(jobs: &mut [J], f: F)
where
    J: Send,
    F: Fn(&mut J) + Sync,
{
    match jobs {
        [] => {}
        [only] => f(only),
        many => std::thread::scope(|scope| {
            // lint:allow(no-unwrap-in-lib): `many` has >= 2 elements, split cannot fail
            let (last, rest) = many.split_last_mut().expect("job");
            for j in rest.iter_mut() {
                let fr = &f;
                scope.spawn(move || fr(j));
            }
            f(last);
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_tile_the_range_exactly() {
        for n in [0usize, 1, 2, 7, 16, 97] {
            for w in 1usize..=9 {
                let mut next = 0usize;
                for i in 0..w {
                    let r = chunk_range(n, w, i);
                    assert_eq!(r.start, next, "n={n} w={w} i={i}");
                    next = r.end;
                    if w <= n {
                        assert!(!r.is_empty(), "n={n} w={w} i={i}");
                    }
                }
                assert_eq!(next, n);
            }
        }
    }

    #[test]
    fn parse_workers_accepts_positive_integers_only() {
        assert_eq!(parse_workers("4"), Some(4));
        assert_eq!(parse_workers(" 12 "), Some(12));
        assert_eq!(parse_workers("0"), None);
        assert_eq!(parse_workers("-3"), None);
        assert_eq!(parse_workers("many"), None);
        assert_eq!(parse_workers(""), None);
    }

    #[test]
    fn parallelism_resolves_to_at_least_one_worker() {
        assert_eq!(Parallelism::Sequential.workers(), 1);
        assert_eq!(Parallelism::Fixed(0).workers(), 1);
        assert_eq!(Parallelism::Fixed(7).workers(), 7);
        assert!(Parallelism::Auto.workers() >= 1);
    }

    #[test]
    fn run_partitioned_matches_serial_for_every_worker_count() {
        let n = 23usize;
        let stride = 3usize;
        let mut expect = vec![0u64; n * stride];
        for t in 0..n {
            for s in 0..stride {
                expect[t * stride + s] = (t * 31 + s) as u64;
            }
        }
        for workers in [1usize, 2, 5, 8, 23, 40] {
            let mut states = vec![0u64; workers]; // per-worker scratch: task counter
            let mut out = vec![0u64; n * stride];
            run_partitioned(&mut states, &mut out, n, stride, |st, chunk, range| {
                for (j, t) in range.enumerate() {
                    *st += 1;
                    for s in 0..stride {
                        chunk[j * stride + s] = (t * 31 + s) as u64;
                    }
                }
            });
            assert_eq!(out, expect, "workers={workers}");
            assert_eq!(states.iter().sum::<u64>(), n as u64, "workers={workers}");
        }
    }

    #[test]
    fn run_scoped_visits_every_job_once() {
        for jobs_n in [0usize, 1, 2, 6] {
            let mut jobs: Vec<(usize, u32)> = (0..jobs_n).map(|i| (i, 0u32)).collect();
            run_scoped(&mut jobs, |j| j.1 += 1);
            for (i, hits) in &jobs {
                assert_eq!(*hits, 1, "job {i}");
            }
        }
    }
}
