//! Minimal JSON emitter/parser (offline stand-in for `serde_json`).
//!
//! Supports the subset the toolkit persists: objects, arrays, strings,
//! finite f64 numbers, bools, null. Used for calibration measurement files,
//! quantization scale files, and experiment records.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_f64()).map(|x| x as f32).collect())
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    // Round-trippable float formatting.
                    let _ = write!(out, "{n}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if a.len() > 8 {
                        pad(out, indent + 1);
                    }
                    v.write(out, indent + 1, pretty && a.len() > 8);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    let _ = write!(out, "\"{k}\":");
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                pad(out, indent);
                out.push('}');
            }
        }
    }

    pub fn parse(s: &str) -> Result<Json, String> {
        let bytes = s.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect_byte(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u".to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u".to_string())?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // UTF-8 passthrough: consume one full codepoint.
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "bad utf8".to_string())?;
                    let c = s.chars().next().ok_or_else(|| "bad utf8".to_string())?;
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect_byte(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let v = self.value()?;
            map.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj(vec![
            ("name", Json::Str("llama".into())),
            ("scales", Json::arr_f32(&[0.5, 2.0, 0.25])),
            ("ok", Json::Bool(true)),
            ("n", Json::Num(3.0)),
            ("none", Json::Null),
        ]);
        let s = j.to_string_pretty();
        let back = Json::parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":"x\ny"}],"c":-1.5e3}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_f64().unwrap(), -1500.0);
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} junk").is_err());
    }

    #[test]
    fn rejects_unterminated() {
        assert!(Json::parse(r#"{"a": "b"#).is_err());
    }

    #[test]
    fn float_roundtrip_precision() {
        let v = 0.062437561f64;
        let s = Json::Num(v).to_string_pretty();
        let back = Json::parse(&s).unwrap().as_f64().unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn unicode_string_roundtrip() {
        let j = Json::Str("héllo ≤ 240 · FP8".into());
        let back = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, back);
    }
}
