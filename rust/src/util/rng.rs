//! Deterministic xorshift64* RNG.
//!
//! Used everywhere randomness is needed (stochastic rounding, synthetic
//! weights, property tests) so that every experiment in EXPERIMENTS.md is
//! exactly reproducible from its seed. `rand` is not available offline.

/// xorshift64* — tiny, fast, passes BigCrush for our purposes.
#[derive(Clone, Debug)]
pub struct XorShiftRng {
    state: u64,
}

impl XorShiftRng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point.
        Self {
            state: seed ^ 0x9E37_79B9_7F4A_7C15 | 1,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box–Muller. Two uniforms per call; we discard the
    /// second root for simplicity (synthetic-weight generation is build-time).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 > 1e-12 {
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Heavy-tailed draw: normal with probability `1-p_outlier`, otherwise
    /// normal scaled by `outlier_scale`. Models activation outlier channels
    /// (the Mistral/Mixtral failure mode under unit scaling in Table 4).
    pub fn outlier_normal(&mut self, p_outlier: f64, outlier_scale: f32) -> f32 {
        let n = self.normal();
        if self.next_f64() < p_outlier {
            n * outlier_scale
        } else {
            n
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = XorShiftRng::new(42);
        let mut b = XorShiftRng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = XorShiftRng::new(1);
        let mut b = XorShiftRng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = XorShiftRng::new(7);
        for _ in 0..10_000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = XorShiftRng::new(11);
        let n = 50_000;
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        for _ in 0..n {
            let v = r.normal() as f64;
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_in_range() {
        let mut r = XorShiftRng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }
}
