//! Minimal dense 2-D f32 tensor.
//!
//! The quantization toolkit works on activations `X` of shape
//! `(samples N × channels C)` and weights `W` of shape `(out C' × in C)`,
//! matching the paper's notation (§3, Eq. 1). Row-major storage. Everything
//! the paper's math needs — per-row/per-column abs-max reductions (Eqs.
//! 8–10), element-wise row/column scaling (Eq. 6), transposed-B matmul
//! (X·Wᵀ), Frobenius norms (Eq. 11) — lives here.

mod matmul;
pub mod stats;

pub use matmul::{matmul, matmul_nt};

use crate::util::rng::XorShiftRng;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor2 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Tensor2 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self { rows, cols, data }
    }

    /// Gaussian tensor with given std — synthetic weights/activations.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut XorShiftRng) -> Self {
        Self::from_fn(rows, cols, |_, _| rng.normal() * std)
    }

    /// Gaussian with heavy-tailed outlier *channels* (columns): each column
    /// has probability `p_outlier_channel` of being scaled by
    /// `outlier_scale`. Models the activation-outlier structure that makes
    /// per-tensor/unit scaling fail on Mistral-class models (paper Table 4).
    pub fn randn_outlier_cols(
        rows: usize,
        cols: usize,
        std: f32,
        p_outlier_channel: f64,
        outlier_scale: f32,
        rng: &mut XorShiftRng,
    ) -> Self {
        let col_scale: Vec<f32> = (0..cols)
            .map(|_| {
                if rng.next_f64() < p_outlier_channel {
                    outlier_scale
                } else {
                    1.0
                }
            })
            .collect();
        Self::from_fn(rows, cols, |_, c| rng.normal() * std * col_scale[c])
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Tensor2 {
        let mut out = Tensor2::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Element-wise map.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor2 {
        Tensor2 {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| f(*x)).collect(),
        }
    }

    /// `self - other`, element-wise.
    pub fn sub(&self, other: &Tensor2) -> Tensor2 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Tensor2 {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a - b)
                .collect(),
        }
    }

    /// Multiply row `r` (all r) by `scales[r]` — `S · X` for diagonal S.
    pub fn scale_rows(&self, scales: &[f32]) -> Tensor2 {
        assert_eq!(scales.len(), self.rows);
        let mut out = self.clone();
        for r in 0..self.rows {
            let s = scales[r];
            for v in out.row_mut(r) {
                *v *= s;
            }
        }
        out
    }

    /// Multiply column `c` (all c) by `scales[c]` — `X · S` for diagonal S
    /// (Eq. 6a: element-wise, not a matrix multiply).
    pub fn scale_cols(&self, scales: &[f32]) -> Tensor2 {
        assert_eq!(scales.len(), self.cols);
        let mut out = self.clone();
        for r in 0..self.rows {
            let row = out.row_mut(r);
            for (v, s) in row.iter_mut().zip(scales) {
                *v *= s;
            }
        }
        out
    }

    /// Frobenius norm squared (Eq. 11).
    pub fn fro_norm_sq(&self) -> f64 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum()
    }

    /// Mean squared error vs another tensor.
    pub fn mse(&self, other: &Tensor2) -> f64 {
        self.sub(other).fro_norm_sq() / self.data.len() as f64
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

pub use stats::{abs_max, col_abs_max, row_abs_max};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_and_access() {
        let t = Tensor2::from_fn(2, 3, |r, c| (r * 10 + c) as f32);
        assert_eq!(t.get(1, 2), 12.0);
        assert_eq!(t.row(0), &[0.0, 1.0, 2.0]);
        assert_eq!(t.numel(), 6);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn from_vec_checks_shape() {
        Tensor2::from_vec(2, 2, vec![1.0; 3]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = XorShiftRng::new(1);
        let t = Tensor2::randn(5, 7, 1.0, &mut rng);
        assert_eq!(t.transpose().transpose(), t);
        assert_eq!(t.transpose().get(3, 2), t.get(2, 3));
    }

    #[test]
    fn row_col_scaling() {
        let t = Tensor2::from_fn(2, 2, |r, c| (1 + r * 2 + c) as f32); // [[1,2],[3,4]]
        let rs = t.scale_rows(&[2.0, 10.0]);
        assert_eq!(rs.data, vec![2.0, 4.0, 30.0, 40.0]);
        let cs = t.scale_cols(&[2.0, 10.0]);
        assert_eq!(cs.data, vec![2.0, 20.0, 6.0, 40.0]);
    }

    #[test]
    fn scaling_inverse_recovers() {
        let mut rng = XorShiftRng::new(2);
        let t = Tensor2::randn(4, 6, 3.0, &mut rng);
        let s: Vec<f32> = (0..6).map(|i| (i + 1) as f32).collect();
        let inv: Vec<f32> = s.iter().map(|x| 1.0 / x).collect();
        let back = t.scale_cols(&s).scale_cols(&inv);
        for (a, b) in back.data.iter().zip(&t.data) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn fro_and_mse() {
        let a = Tensor2::from_vec(1, 3, vec![1.0, 2.0, 2.0]);
        assert_eq!(a.fro_norm_sq(), 9.0);
        let b = Tensor2::from_vec(1, 3, vec![1.0, 2.0, 5.0]);
        assert_eq!(a.mse(&b), 3.0);
    }

    #[test]
    fn outlier_cols_have_outliers() {
        let mut rng = XorShiftRng::new(3);
        let t = Tensor2::randn_outlier_cols(256, 64, 1.0, 0.05, 50.0, &mut rng);
        let col_max = stats::col_abs_max(&t);
        let big = col_max.iter().filter(|m| **m > 20.0).count();
        assert!(big >= 1, "expected some outlier channels");
        let small = col_max.iter().filter(|m| **m < 10.0).count();
        assert!(small > 48, "most channels should be ordinary");
    }
}
