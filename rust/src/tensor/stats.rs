//! Reductions used by calibration (paper Eqs. 8–10).

use super::Tensor2;

/// Per-tensor max-abs: `r_x = max |X|` (Eq. 8a / 10a).
pub fn abs_max(t: &Tensor2) -> f32 {
    t.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
}

/// Per-row max-abs. For activations (N×C) this is the *per-sample* statistic
/// (Eq. 9b); for weights (C'×C) it is the *per-output-channel* statistic
/// (Eq. 10b).
pub fn row_abs_max(t: &Tensor2) -> Vec<f32> {
    (0..t.rows)
        .map(|r| t.row(r).iter().fold(0.0f32, |m, x| m.max(x.abs())))
        .collect()
}

/// Per-column max-abs. For activations this is the *per-channel* statistic
/// (Eq. 8b); for weights the *per-input-channel* statistic (Eq. 10c).
pub fn col_abs_max(t: &Tensor2) -> Vec<f32> {
    let mut out = vec![0.0f32; t.cols];
    for r in 0..t.rows {
        for (m, x) in out.iter_mut().zip(t.row(r)) {
            *m = m.max(x.abs());
        }
    }
    out
}

/// Per-tensor mean absolute value — one of the statistics §3.1 lists.
pub fn abs_mean(t: &Tensor2) -> f32 {
    if t.data.is_empty() {
        return 0.0;
    }
    (t.data.iter().map(|x| x.abs() as f64).sum::<f64>() / t.data.len() as f64) as f32
}

/// (min, max) — §3.1's min/max statistic.
pub fn min_max(t: &Tensor2) -> (f32, f32) {
    t.data.iter().fold((f32::INFINITY, f32::NEG_INFINITY), |(lo, hi), x| {
        (lo.min(*x), hi.max(*x))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> Tensor2 {
        Tensor2::from_vec(2, 3, vec![1.0, -5.0, 2.0, -3.0, 4.0, 0.5])
    }

    #[test]
    fn abs_max_is_global() {
        assert_eq!(abs_max(&t()), 5.0);
    }

    #[test]
    fn row_abs_max_per_sample() {
        assert_eq!(row_abs_max(&t()), vec![5.0, 4.0]);
    }

    #[test]
    fn col_abs_max_per_channel() {
        assert_eq!(col_abs_max(&t()), vec![3.0, 5.0, 2.0]);
    }

    #[test]
    fn consistency_between_granularities() {
        // max of per-row == max of per-col == per-tensor (Eqs. 8–10 coherence).
        let mut rng = crate::util::rng::XorShiftRng::new(9);
        let x = Tensor2::randn(17, 23, 2.0, &mut rng);
        let rt = abs_max(&x);
        let rows = row_abs_max(&x);
        let cols = col_abs_max(&x);
        let max_r = rows.iter().fold(0.0f32, |a, b| a.max(*b));
        let max_c = cols.iter().fold(0.0f32, |a, b| a.max(*b));
        assert_eq!(rt, max_r);
        assert_eq!(rt, max_c);
    }

    #[test]
    fn abs_mean_and_minmax() {
        let x = Tensor2::from_vec(1, 4, vec![-2.0, 2.0, -2.0, 2.0]);
        assert_eq!(abs_mean(&x), 2.0);
        assert_eq!(min_max(&x), (-2.0, 2.0));
    }
}
