//! f32 matrix multiplication — the high-precision reference path.
//!
//! `matmul_nt` computes `X · Wᵀ` (Eq. 1) directly from the paper's layouts
//! (X: N×C, W: C'×C) as row-dot-row, which is cache-friendly without a
//! transpose. A blocked variant is used for larger shapes.

use super::Tensor2;

/// `A (m×k) · B (k×n) → (m×n)`.
pub fn matmul(a: &Tensor2, b: &Tensor2) -> Tensor2 {
    assert_eq!(a.cols, b.rows, "inner dims");
    // Implemented via matmul_nt on Bᵀ to reuse the tuned kernel.
    let bt = b.transpose();
    matmul_nt(a, &bt)
}

/// `X (N×C) · Wᵀ → (N×C')` where `W` is `C'×C` — the paper's linear layer.
/// f32 accumulation in f64 is NOT used: f32 matches the Gaudi FP32
/// accumulator semantics.
pub fn matmul_nt(x: &Tensor2, w: &Tensor2) -> Tensor2 {
    assert_eq!(x.cols, w.cols, "inner dims (channels)");
    let (n, c, k) = (x.rows, x.cols, w.rows);
    let mut out = Tensor2::zeros(n, k);
    // Register-blocked 1×4 over output columns; dot products over rows.
    let kb = k / 4 * 4;
    for i in 0..n {
        let xi = x.row(i);
        let orow = out.row_mut(i);
        let mut j = 0;
        while j < kb {
            let (w0, w1, w2, w3) = (w.row(j), w.row(j + 1), w.row(j + 2), w.row(j + 3));
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for t in 0..c {
                let xv = xi[t];
                a0 += xv * w0[t];
                a1 += xv * w1[t];
                a2 += xv * w2[t];
                a3 += xv * w3[t];
            }
            orow[j] = a0;
            orow[j + 1] = a1;
            orow[j + 2] = a2;
            orow[j + 3] = a3;
            j += 4;
        }
        while j < k {
            let wj = w.row(j);
            let mut acc = 0.0f32;
            for t in 0..c {
                acc += xi[t] * wj[t];
            }
            orow[j] = acc;
            j += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShiftRng;

    fn naive_nt(x: &Tensor2, w: &Tensor2) -> Tensor2 {
        let mut out = Tensor2::zeros(x.rows, w.rows);
        for i in 0..x.rows {
            for j in 0..w.rows {
                let mut acc = 0.0f64;
                for t in 0..x.cols {
                    acc += (x.get(i, t) as f64) * (w.get(j, t) as f64);
                }
                out.set(i, j, acc as f32);
            }
        }
        out
    }

    #[test]
    fn small_known_product() {
        // X = [[1,2],[3,4]], W = [[1,1],[0,2]] → X·Wᵀ = [[3,4],[7,8]]
        let x = Tensor2::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let w = Tensor2::from_vec(2, 2, vec![1.0, 1.0, 0.0, 2.0]);
        let o = matmul_nt(&x, &w);
        assert_eq!(o.data, vec![3.0, 4.0, 7.0, 8.0]);
    }

    #[test]
    fn matmul_vs_matmul_nt() {
        let mut rng = XorShiftRng::new(6);
        let x = Tensor2::randn(5, 8, 1.0, &mut rng);
        let w = Tensor2::randn(7, 8, 1.0, &mut rng);
        let a = matmul_nt(&x, &w);
        let b = matmul(&x, &w.transpose());
        for (p, q) in a.data.iter().zip(&b.data) {
            assert!((p - q).abs() < 1e-5);
        }
    }

    #[test]
    fn blocked_matches_naive_odd_shapes() {
        let mut rng = XorShiftRng::new(8);
        for (n, c, k) in [(1, 1, 1), (3, 5, 7), (16, 33, 9), (8, 64, 6), (2, 7, 4)] {
            let x = Tensor2::randn(n, c, 1.0, &mut rng);
            let w = Tensor2::randn(k, c, 1.0, &mut rng);
            let fast = matmul_nt(&x, &w);
            let slow = naive_nt(&x, &w);
            for (p, q) in fast.data.iter().zip(&slow.data) {
                assert!((p - q).abs() <= 1e-4 * q.abs().max(1.0), "{n}x{c}x{k}: {p} vs {q}");
            }
        }
    }

    #[test]
    fn identity_weight_is_identity() {
        let mut rng = XorShiftRng::new(10);
        let x = Tensor2::randn(4, 6, 1.0, &mut rng);
        let eye = Tensor2::from_fn(6, 6, |r, c| if r == c { 1.0 } else { 0.0 });
        let o = matmul_nt(&x, &eye);
        assert_eq!(o.data, x.data);
    }

    #[test]
    #[should_panic(expected = "inner dims")]
    fn shape_mismatch_panics() {
        let x = Tensor2::zeros(2, 3);
        let w = Tensor2::zeros(2, 4);
        matmul_nt(&x, &w);
    }
}
