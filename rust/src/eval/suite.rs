//! Accuracy suite over SyntheticLm models.

use crate::fp8::Fp8Format;
use crate::model::config::ModelConfig;
use crate::model::synthetic::SyntheticLm;
use crate::quant::QuantScheme;
use crate::tensor::Tensor2;
use crate::util::rng::XorShiftRng;

#[derive(Clone, Debug)]
pub struct EvalConfig {
    pub classes: usize,
    pub calib_samples: usize,
    pub eval_samples: usize,
    pub seed: u64,
    pub format: Fp8Format,
}

impl Default for EvalConfig {
    fn default() -> Self {
        Self {
            classes: 64,
            calib_samples: 128,
            eval_samples: 512,
            seed: 2024,
            format: Fp8Format::E4M3Gaudi2,
        }
    }
}

/// One row of a Tables-2–4-style report.
#[derive(Clone, Debug)]
pub struct AccuracyRow {
    pub configuration: String,
    pub ppl: f64,
    pub ppl_delta_pct: f64,
    pub commonsense_acc: f64,
    pub commonsense_delta_pct: f64,
    pub mmlu_acc: f64,
    pub mmlu_delta_pct: f64,
}

fn softmax_row(row: &[f32]) -> Vec<f64> {
    let m = row.iter().fold(f32::NEG_INFINITY, |a, b| a.max(*b)) as f64;
    let exps: Vec<f64> = row.iter().map(|v| ((*v as f64) - m).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / z).collect()
}

fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map_or(0, |(i, _)| i)
}

/// Margin = top1 − top2 of the reference logits; splits examples into the
/// robust ("common sense") and sensitive ("MMLU") populations.
fn margin(row: &[f32]) -> f32 {
    let mut a = f32::NEG_INFINITY;
    let mut b = f32::NEG_INFINITY;
    for &v in row {
        if v > a {
            b = a;
            a = v;
        } else if v > b {
            b = v;
        }
    }
    a - b
}

struct Metrics {
    ppl: f64,
    commonsense: f64,
    mmlu: f64,
}

fn metrics(
    logits: &Tensor2,
    labels: &[usize],
    ref_logits: &Tensor2,
    margin_split: f32,
) -> Metrics {
    let n = logits.rows;
    let mut nll = 0.0f64;
    let (mut cs_ok, mut cs_n, mut mm_ok, mut mm_n) = (0usize, 0usize, 0usize, 0usize);
    for i in 0..n {
        let p = softmax_row(logits.row(i));
        nll -= p[labels[i]].max(1e-12).ln();
        let pred = argmax(logits.row(i));
        let ok = pred == labels[i];
        if margin(ref_logits.row(i)) >= margin_split {
            cs_n += 1;
            cs_ok += ok as usize;
        } else {
            mm_n += 1;
            mm_ok += ok as usize;
        }
    }
    Metrics {
        ppl: (nll / n as f64).exp(),
        commonsense: 100.0 * cs_ok as f64 / cs_n.max(1) as f64,
        mmlu: 100.0 * mm_ok as f64 / mm_n.max(1) as f64,
    }
}

/// Evaluate one model config across schemes. Returns rows: BF16 reference
/// first, then each scheme with Δ% columns (the paper's table layout).
pub fn evaluate_model(
    cfg: &ModelConfig,
    schemes: &[(String, QuantScheme)],
    ec: &EvalConfig,
) -> Vec<AccuracyRow> {
    let lm = SyntheticLm::new(cfg, ec.classes, ec.seed);
    let mut rng = XorShiftRng::new(ec.seed ^ 0x5EED);
    let x_cal = lm.sample_inputs(ec.calib_samples, &mut rng);
    let x_eval = lm.sample_inputs(ec.eval_samples, &mut rng);
    let stats = lm.calibrate(&x_cal);

    let ref_logits = lm.forward_reference(&x_eval);
    // Labels: reference argmax (the model's own "truth") — Δ measures how
    // quantization perturbs the model away from its reference behaviour.
    let labels: Vec<usize> = (0..ref_logits.rows)
        .map(|i| argmax(ref_logits.row(i)))
        .collect();
    // Margin split point: median margin → halves form the two populations.
    let mut margins: Vec<f32> = (0..ref_logits.rows)
        .map(|i| margin(ref_logits.row(i)))
        .collect();
    margins.sort_by(|a, b| a.total_cmp(b));
    let split = margins[margins.len() / 2];

    let base = metrics(&ref_logits, &labels, &ref_logits, split);
    let mut rows = vec![AccuracyRow {
        configuration: "BF16 Reference".into(),
        ppl: base.ppl,
        ppl_delta_pct: 0.0,
        commonsense_acc: base.commonsense,
        commonsense_delta_pct: 0.0,
        mmlu_acc: base.mmlu,
        mmlu_delta_pct: 0.0,
    }];

    for (name, scheme) in schemes {
        let q_logits = lm.forward_quantized(&x_eval, *scheme, &stats);
        let m = metrics(&q_logits, &labels, &ref_logits, split);
        rows.push(AccuracyRow {
            configuration: name.clone(),
            ppl: m.ppl,
            ppl_delta_pct: 100.0 * (m.ppl - base.ppl) / base.ppl,
            commonsense_acc: m.commonsense,
            commonsense_delta_pct: m.commonsense - base.commonsense,
            mmlu_acc: m.mmlu,
            mmlu_delta_pct: m.mmlu - base.mmlu,
        });
    }
    rows
}

/// The Tables 2–4 scheme grid.
pub fn paper_schemes(format: Fp8Format) -> Vec<(String, QuantScheme)> {
    vec![
        ("Unit Scale".into(), QuantScheme::unit_scale(format)),
        ("Per Tensor Scaling".into(), QuantScheme::per_tensor(format)),
        ("Per Channel Scaling".into(), QuantScheme::per_channel(format)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelFamily;

    fn quick_ec() -> EvalConfig {
        EvalConfig {
            eval_samples: 128,
            calib_samples: 64,
            ..Default::default()
        }
    }

    #[test]
    fn reference_row_is_self_consistent() {
        let cfg = ModelConfig::synthetic_tiny(ModelFamily::Llama2);
        let rows = evaluate_model(&cfg, &paper_schemes(Fp8Format::E4M3Gaudi2), &quick_ec());
        assert_eq!(rows.len(), 4);
        // BF16 row: accuracy on its own labels = 100%.
        assert_eq!(rows[0].commonsense_acc, 100.0);
        assert_eq!(rows[0].mmlu_acc, 100.0);
        assert!(rows[0].ppl >= 1.0);
    }

    #[test]
    fn llama_family_degradation_small_for_scaled_schemes() {
        let cfg = ModelConfig::synthetic_small(ModelFamily::Llama2);
        let rows = evaluate_model(&cfg, &paper_schemes(Fp8Format::E4M3Gaudi2), &quick_ec());
        for row in &rows[2..] {
            // Per-tensor / per-channel: commonsense within a few points
            // (paper: "typically below 1%"; our tiny models are noisier).
            assert!(
                row.commonsense_delta_pct.abs() < 8.0,
                "{}: cs Δ {}",
                row.configuration,
                row.commonsense_delta_pct
            );
        }
    }

    #[test]
    fn mmlu_more_sensitive_than_commonsense() {
        // §4.2.2: small-margin (knowledge) tasks degrade more.
        let cfg = ModelConfig::synthetic_tiny(ModelFamily::Llama2);
        let rows = evaluate_model(&cfg, &paper_schemes(Fp8Format::E4M3Gaudi2), &quick_ec());
        let pt = &rows[2]; // per-tensor
        assert!(
            pt.mmlu_delta_pct <= pt.commonsense_delta_pct + 1e-9,
            "mmlu Δ {} should be ≤ cs Δ {}",
            pt.mmlu_delta_pct,
            pt.commonsense_delta_pct
        );
    }

    #[test]
    fn mistral_unit_scale_collapses() {
        // Table 4's structure: unit-scale PPL explodes on outlier families.
        let cfg = ModelConfig::synthetic_tiny(ModelFamily::Mistral);
        let rows = evaluate_model(&cfg, &paper_schemes(Fp8Format::E4M3Gaudi2), &quick_ec());
        let unit = &rows[1];
        let pt = &rows[2];
        assert!(
            unit.ppl_delta_pct > 5.0 * pt.ppl_delta_pct.max(0.5),
            "unit Δppl {} vs pt {}",
            unit.ppl_delta_pct,
            pt.ppl_delta_pct
        );
        assert!(
            unit.commonsense_delta_pct < -5.0,
            "unit cs Δ should collapse (got {})",
            unit.commonsense_delta_pct
        );
    }
}
