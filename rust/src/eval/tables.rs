//! Render accuracy rows in the paper's table format.

use super::suite::AccuracyRow;
use crate::util::render_table;

/// Tables 2–4 layout: PPL (Acc↓, Δ%↓), Common sense (Acc↑, Δ%↑),
/// MMLU (Acc↑, Δ%↑).
pub fn render_accuracy_table(model_name: &str, rows: &[AccuracyRow]) -> String {
    let header = [
        "Configuration",
        "PPL Acc↓",
        "PPL Δ(%)↓",
        "CSense Acc↑",
        "CSense Δ↑",
        "MMLU Acc↑",
        "MMLU Δ↑",
    ];
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let d = |v: f64, is_ref: bool| {
                if is_ref {
                    "_".to_string()
                } else {
                    format!("{v:+.2}")
                }
            };
            let is_ref = r.configuration == "BF16 Reference";
            vec![
                r.configuration.clone(),
                format!("{:.3}", r.ppl),
                d(r.ppl_delta_pct, is_ref),
                format!("{:.3}", r.commonsense_acc),
                d(r.commonsense_delta_pct, is_ref),
                format!("{:.3}", r.mmlu_acc),
                d(r.mmlu_delta_pct, is_ref),
            ]
        })
        .collect();
    render_table(
        &format!("{model_name} accuracy for various quantization methods"),
        &header,
        &body,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_reference_row_with_dashes() {
        let rows = vec![
            AccuracyRow {
                configuration: "BF16 Reference".into(),
                ppl: 13.066,
                ppl_delta_pct: 0.0,
                commonsense_acc: 67.388,
                commonsense_delta_pct: 0.0,
                mmlu_acc: 43.085,
                mmlu_delta_pct: 0.0,
            },
            AccuracyRow {
                configuration: "Unit Scale".into(),
                ppl: 14.143,
                ppl_delta_pct: 8.24,
                commonsense_acc: 67.102,
                commonsense_delta_pct: -0.42,
                mmlu_acc: 42.483,
                mmlu_delta_pct: -1.40,
            },
        ];
        let t = render_accuracy_table("Llama2-7B", &rows);
        assert!(t.contains("Llama2-7B"));
        assert!(t.contains("| _"));
        assert!(t.contains("+8.24"));
        assert!(t.contains("-0.42"));
    }
}
