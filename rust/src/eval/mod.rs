//! Accuracy evaluation harness — regenerates the structure of Tables 2–4.
//!
//! Metrics (synthetic analogues of the paper's three columns):
//! * **PPL** — perplexity of the quantized model against labels drawn from
//!   the reference model (WikiText2 stand-in); reported as Δ% vs BF16 where
//!   lower/smaller Δ is better.
//! * **Common sense** — top-1 agreement with the reference on *large-margin*
//!   examples: robust reasoning-style tasks degrade little (§4.2.2).
//! * **MMLU** — top-1 agreement restricted to *small-margin* examples:
//!   knowledge-retrieval tasks sit near decision boundaries and are more
//!   quantization-sensitive (§4.2.2).

pub mod suite;
pub mod tables;

pub use suite::{evaluate_model, AccuracyRow, EvalConfig};
pub use tables::render_accuracy_table;
