"""Pallas kernels vs the numpy oracle (ref.py) — the CORE correctness
signal for L1. Hypothesis sweeps shapes including ragged (non-block-
multiple) dims, which exercise the padding paths."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fp8_jnp as F
from compile.kernels import ref as R
from compile.kernels.fp8_cast import (
    dequantize_per_tensor,
    quantize_per_row,
    quantize_per_tensor,
)
from compile.kernels.scaled_matmul import fused_quant_matmul_fp8, scaled_matmul_fp8

SPECS = [F.E4M3_GAUDI2, F.E4M3, F.E5M2]
IDS = [s.name for s in SPECS]


@pytest.mark.parametrize("spec", SPECS, ids=IDS)
@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 70),
    c=st.integers(1, 70),
    seed=st.integers(0, 2**16),
)
def test_cast_kernel_exact_vs_oracle(spec, n, c, seed):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((n, c)) * spec.max_normal / 4).astype(np.float32)
    s = R.per_tensor_scale_ref(x, spec)
    got = np.asarray(quantize_per_tensor(jnp.asarray(x), jnp.float32(s), spec))
    want = R.quantize_ref(x, s, spec)
    table = F.decode_table_np(spec)
    np.testing.assert_array_equal(table[got], table[want])


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 40), c=st.integers(1, 50), seed=st.integers(0, 999))
def test_per_row_cast_kernel(n, c, seed):
    spec = F.E4M3
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((n, c)) * 10).astype(np.float32)
    s = R.per_row_scale_ref(x, spec)
    got = np.asarray(quantize_per_row(jnp.asarray(x), jnp.asarray(s), spec))
    want = R.quantize_ref(x, s, spec)
    table = F.decode_table_np(spec)
    np.testing.assert_array_equal(table[got], table[want])


@pytest.mark.parametrize("spec", SPECS, ids=IDS)
@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 80),
    n=st.integers(1, 24),
    seed=st.integers(0, 999),
)
def test_scaled_matmul_kernel_vs_oracle(spec, m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, k)).astype(np.float32)
    w = (rng.standard_normal((n, k)) * 0.1).astype(np.float32)
    s_x = R.per_tensor_scale_ref(x, spec)
    s_w = R.per_row_scale_ref(w, spec)
    xq = R.quantize_ref(x, s_x, spec)
    wq = R.quantize_ref(w, s_w, spec)
    got = np.asarray(
        scaled_matmul_fp8(
            jnp.asarray(xq),
            jnp.asarray(wq),
            jnp.full((m,), s_x, jnp.float32),
            jnp.asarray(s_w),
            spec,
        )
    )
    want = R.scaled_matmul_ref(x, w, s_x, s_w, spec)
    scale = np.max(np.abs(want)) + 1e-6
    assert np.max(np.abs(got - want)) / scale < 1e-5


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 33), k=st.integers(1, 600), seed=st.integers(0, 99))
def test_fused_kernel_matches_two_pass(m, k, seed):
    """Fused JiT quantize+GEMM ≡ separate cast then GEMM (§2.3.2)."""
    spec = F.E4M3_GAUDI2
    n = 16
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((m, k)) * 3).astype(np.float32)
    w = (rng.standard_normal((n, k)) * 0.05).astype(np.float32)
    s_x = R.per_tensor_scale_ref(x, spec)
    s_w = R.per_row_scale_ref(w, spec)
    wq = R.quantize_ref(w, s_w, spec)
    fused = np.asarray(
        fused_quant_matmul_fp8(
            jnp.asarray(x),
            jnp.asarray(wq),
            jnp.full((m,), s_x, jnp.float32),
            jnp.asarray(s_w),
            spec,
        )
    )
    xq = R.quantize_ref(x, s_x, spec)
    twopass = np.asarray(
        scaled_matmul_fp8(
            jnp.asarray(xq),
            jnp.asarray(wq),
            jnp.full((m,), s_x, jnp.float32),
            jnp.asarray(s_w),
            spec,
        )
    )
    scale = np.max(np.abs(twopass)) + 1e-6
    assert np.max(np.abs(fused - twopass)) / scale < 1e-6


def test_dequantize_kernel():
    spec = F.E4M3
    rng = np.random.default_rng(0)
    x = (rng.standard_normal((37, 53)) * 5).astype(np.float32)
    s = R.per_tensor_scale_ref(x, spec)
    codes = R.quantize_ref(x, s, spec)
    got = np.asarray(dequantize_per_tensor(jnp.asarray(codes), s, spec))
    want = F.decode_table_np(spec)[codes] * np.float32(s)
    np.testing.assert_array_equal(got, want)


def test_quantization_improves_with_scaling():
    """Unit-vs-scaled on outlier activations: the Table 4 mechanism at the
    kernel level."""
    spec = F.E4M3_GAUDI2
    rng = np.random.default_rng(3)
    x = rng.standard_normal((32, 256)).astype(np.float32)
    x[:, :8] *= 400.0  # outlier channels beyond ±240
    w = (rng.standard_normal((16, 256)) * 0.05).astype(np.float32)
    ref_out = x @ w.T

    def err(s_x):
        s_w = R.per_row_scale_ref(w, spec)
        wq = R.quantize_ref(w, s_w, spec)
        out = np.asarray(
            fused_quant_matmul_fp8(
                jnp.asarray(x),
                jnp.asarray(wq),
                jnp.full((32,), s_x, jnp.float32),
                jnp.asarray(s_w),
                spec,
            )
        )
        return np.linalg.norm(out - ref_out) / np.linalg.norm(ref_out)

    e_unit = err(np.float32(1.0))
    e_scaled = err(np.float32(R.per_tensor_scale_ref(x, spec)))
    assert e_unit > 3 * e_scaled, (e_unit, e_scaled)
