"""L1 numerics: the jittable FP8 encoder/decoder vs the table-search oracle.

Hypothesis sweeps shapes/values; exhaustive code-space checks pin the
format semantics (paper §2, §2.4).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fp8_jnp as F
from compile.kernels import ref as R

SPECS = [F.E4M3_GAUDI2, F.E4M3, F.E5M2]
IDS = [s.name for s in SPECS]


@pytest.mark.parametrize("spec", SPECS, ids=IDS)
def test_decode_matches_table_exhaustive(spec):
    codes = jnp.arange(256, dtype=jnp.uint32).astype(jnp.uint8)
    got = np.asarray(F.decode(codes, spec))
    table = F.decode_table_np(spec)
    for c in range(256):
        a, b = got[c], table[c]
        assert (np.isnan(a) and np.isnan(b)) or a == b, f"code {c:#04x}: {a} vs {b}"


@pytest.mark.parametrize("spec", SPECS, ids=IDS)
def test_roundtrip_every_finite_code(spec):
    table = F.decode_table_np(spec)
    finite = np.isfinite(table)
    vals = table[finite]
    codes = np.asarray(F.encode_rne(jnp.asarray(vals), spec))
    back = table[codes]
    np.testing.assert_array_equal(back, vals)


@pytest.mark.parametrize("spec", SPECS, ids=IDS)
def test_ranges_match_paper(spec):
    expected = {"e4m3_gaudi2": 240.0, "e4m3": 448.0, "e5m2": 57344.0}[spec.name]
    assert spec.max_normal == expected
    # Saturating cast clips to max (paper §1).
    c = F.encode_rne(jnp.asarray([1e9, -1e9], jnp.float32), spec)
    got = np.asarray(F.decode(c, spec))
    np.testing.assert_array_equal(got, [expected, -expected])


@pytest.mark.parametrize("spec", SPECS, ids=IDS)
@settings(max_examples=200, deadline=None)
@given(data=st.data())
def test_encode_matches_oracle_hypothesis(spec, data):
    xs = data.draw(
        st.lists(
            st.floats(
                min_value=-1e6,
                max_value=1e6,
                allow_nan=False,
                width=32,
            ),
            min_size=1,
            max_size=64,
        )
    )
    x = np.asarray(xs, np.float32)
    fast = np.asarray(F.encode_rne(jnp.asarray(x), spec))
    slow = R.encode_nearest_oracle(x, spec)
    table = F.decode_table_np(spec)
    va, vb = table[fast], table[slow]
    both_nan = np.isnan(va) & np.isnan(vb)
    assert np.all(both_nan | (va == vb)), f"{x[(va != vb) & ~both_nan]}"


@pytest.mark.parametrize("spec", SPECS, ids=IDS)
def test_midpoints_round_to_even(spec):
    table = F.decode_table_np(spec)
    pos = np.sort(table[np.isfinite(table) & (table > 0)])
    mids = (pos[:-1] + pos[1:]) / 2
    codes = np.asarray(F.encode_rne(jnp.asarray(mids, jnp.float32), spec))
    # Ties to even mantissa ⇒ resulting code is even.
    assert np.all(codes % 2 == 0), mids[codes % 2 != 0]


@pytest.mark.parametrize("spec", SPECS, ids=IDS)
def test_subnormal_region(spec):
    tiny = spec.max_normal * 0.0  # zero
    min_sub = 2.0 ** (spec.min_normal_exp - spec.man_bits)
    x = jnp.asarray([tiny, min_sub, min_sub / 2, min_sub / 4, -min_sub], jnp.float32)
    got = np.asarray(F.decode(F.encode_rne(x, spec), spec))
    # min_sub/2 ties to even → 0; min_sub/4 rounds down to 0.
    np.testing.assert_array_equal(got, [0.0, min_sub, 0.0, 0.0, -min_sub])


def test_nan_propagates():
    for spec in SPECS:
        c = F.encode_rne(jnp.asarray([np.nan], jnp.float32), spec)
        assert np.isnan(np.asarray(F.decode(c, spec))[0])


def test_gaudi2_vs_gaudi3_range_difference():
    # §2.4: the same value 300 saturates to 240 on Gaudi 2, encodes ~288 on
    # Gaudi 3 (nearest representable).
    x = jnp.asarray([300.0], jnp.float32)
    g2 = np.asarray(F.decode(F.encode_rne(x, F.E4M3_GAUDI2), F.E4M3_GAUDI2))[0]
    g3 = np.asarray(F.decode(F.encode_rne(x, F.E4M3), F.E4M3))[0]
    assert g2 == 240.0
    assert g3 == 288.0
