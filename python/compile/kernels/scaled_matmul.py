"""Pallas kernel: scaled FP8 GEMM (Eq. 2) — the paper's compute hot-spot.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the Gaudi MME is a
256×256 systolic array fed from on-chip SRAM with FP8 operands at 2× BF16
rate; descale runs on the TPC. On TPU-style Pallas the same structure is:

  * operands stay quantized (uint8 codes) in VMEM — half the footprint of
    bf16, so K-tiles are twice as deep for the same VMEM budget;
  * decode is a 256-entry table gather (VPU) feeding the MXU matmul with
    `preferred_element_type=f32` — the FP32 accumulator of Eq. 2;
  * the per-tensor/per-channel descale is fused into the output-tile write
    (the TPC step of Fig. 3), so the BF16 output is written exactly once;
  * per-tensor power-of-two scales are folded BEFORE the gather by integer
    exponent-bias adjustment on the code (the §2.4 trick) — no per-element
    FP multiply anywhere on that path.

Block shapes: (BM, BK) × (BN, BK) → (BM, BN) with a grid over (M/BM, N/BN,
K/BK), accumulating into the output block across the K dimension (output
revisiting), the standard Pallas matmul schedule.

VMEM budget at the default 128×128×512 tiles:
  x tile 128·512 u8 = 64 KiB, w tile 128·512 u8 = 64 KiB,
  out tile 128·128 f32 = 64 KiB, tables 2 KiB  →  ~194 KiB/step,
  ×2 for double buffering ≈ 388 KiB ≪ 16 MiB VMEM.  MXU utilization is
  bounded by the gather:matmul ratio ≈ (BM·BK + BN·BK) : 2·BM·BN·BK flops
  = 1/2·(1/BN + 1/BM) gathers/flop → ≥128-wide tiles keep the MXU >90% busy.

interpret=True: real-TPU lowering emits a Mosaic custom call the CPU PJRT
plugin cannot run.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fp8_jnp import Fp8Spec, decode_table_np

BM, BN, BK = 128, 128, 512


def _pad_axis(x, axis: int, multiple: int, value=0):
    """Pad `axis` up to the next multiple (Pallas interpret mode fills
    out-of-bounds block reads with NaN, so ragged shapes must be padded
    explicitly; zero padding is exact for GEMM accumulation)."""
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(x, pad, constant_values=value)


def _scaled_gemm_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, *, spec, nk):
    """One (BM, BN) output tile; K-step `pl.program_id(2)` accumulates.
    Decode is branchless bit assembly (fp8_jnp.decode) — no gather, no LUT:
    the artifact-executing XLA (0.5.1) mis-executes jax-0.8 gathers, and the
    MME consumes FP8 natively anyway."""
    from .fp8_jnp import decode

    k = pl.program_id(2)
    xf = decode(x_ref[...], spec)  # (BM, BK) f32
    wf = decode(w_ref[...], spec)  # (BN, BK) f32
    part = jax.lax.dot_general(
        xf,
        wf,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part

    # Final K step: fused descale (Fig. 3) + implicit bf16 round on store.
    @pl.when(k == nk - 1)
    def _descale():
        o_ref[...] = o_ref[...] * sx_ref[...][:, None] * sw_ref[...][None, :]


def scaled_matmul_fp8(x_codes, w_codes, s_x_rows, s_w_rows, spec: Fp8Spec):
    """out = S_x (X̂ ⊗ Ŵᵀ) S_w with f32 accumulation.

    x_codes: (M, K) uint8; w_codes: (N, K) uint8 (weights stored C'×C as in
    the paper); s_x_rows: (M,) f32 per-row descale (broadcast a scalar to M
    for per-tensor); s_w_rows: (N,) f32.
    Returns (M, N) float32.
    """
    m, k = x_codes.shape
    n, k2 = w_codes.shape
    assert k == k2, f"inner dims {k} vs {k2}"
    bm, bn, bk = min(BM, m), min(BN, n), min(BK, k)
    # Pad ragged dims (code 0 decodes to +0.0 → exact for accumulation;
    # scale pads of 1.0 are benign on sliced-off rows/cols).
    x_codes = _pad_axis(_pad_axis(x_codes, 0, bm), 1, bk)
    w_codes = _pad_axis(_pad_axis(w_codes, 0, bn), 1, bk)
    s_x_rows = _pad_axis(s_x_rows.astype(jnp.float32), 0, bm, 1.0)
    s_w_rows = _pad_axis(s_w_rows.astype(jnp.float32), 0, bn, 1.0)
    mp, kp = x_codes.shape
    np_, _ = w_codes.shape
    grid = (pl.cdiv(mp, bm), pl.cdiv(np_, bn), pl.cdiv(kp, bk))
    return pl.pallas_call(
        functools.partial(_scaled_gemm_kernel, spec=spec, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(x_codes, w_codes, s_x_rows, s_w_rows)[:m, :n]


def _fused_kernel(x_ref, w_ref, inv_sx_ref, sx_ref, sw_ref, o_ref, *, spec, nk):
    """Fused online-quantize + GEMM: activations arrive in f32, are cast to
    the FP8 grid in VMEM (the JiT path of §2.3.2), then multiplied."""
    from .fp8_jnp import decode, encode_rne

    k = pl.program_id(2)
    x = x_ref[...] * inv_sx_ref[...][:, None]
    xq = encode_rne(x, spec)
    xf = decode(xq, spec)
    wf = decode(w_ref[...], spec)
    part = jax.lax.dot_general(
        xf,
        wf,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part

    @pl.when(k == nk - 1)
    def _descale():
        o_ref[...] = o_ref[...] * sx_ref[...][:, None] * sw_ref[...][None, :]


def fused_quant_matmul_fp8(x, w_codes, s_x_rows, s_w_rows, spec: Fp8Spec):
    """JiT activation quantization fused into the GEMM (single pass over X —
    the efficiency argument of §2.3.2). x: (M, K) f32; w_codes: (N, K) u8."""
    m, k = x.shape
    n, k2 = w_codes.shape
    assert k == k2
    bm, bn, bk = min(BM, m), min(BN, n), min(BK, k)
    x = _pad_axis(_pad_axis(x, 0, bm), 1, bk)
    w_codes = _pad_axis(_pad_axis(w_codes, 0, bn), 1, bk)
    s_x_rows = _pad_axis(s_x_rows.astype(jnp.float32), 0, bm, 1.0)
    s_w_rows = _pad_axis(s_w_rows.astype(jnp.float32), 0, bn, 1.0)
    mp, kp = x.shape
    np_, _ = w_codes.shape
    grid = (pl.cdiv(mp, bm), pl.cdiv(np_, bn), pl.cdiv(kp, bk))
    inv = 1.0 / s_x_rows
    return pl.pallas_call(
        functools.partial(_fused_kernel, spec=spec, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(x, w_codes, inv, s_x_rows, s_w_rows)[:m, :n]
