"""Pallas kernel: FP8 quantize (cast) with optional scaling.

The Gaudi TPC performs the high-precision → FP8 cast as an elementwise
stream; on TPU-style Pallas the analogue is a VPU elementwise kernel over
VMEM tiles. `interpret=True` everywhere — real-TPU lowering would emit a
Mosaic custom call the CPU PJRT client cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fp8_jnp import Fp8Spec, encode_rne, decode_table_np

# Tile sizes chosen for VMEM residency: 256×256 f32 in + u8 out ≈ 320 KiB,
# comfortably inside a 16 MiB VMEM budget with double buffering.
BLOCK_ROWS = 256
BLOCK_COLS = 256


def _pad2(x, br, bc, value=0):
    """Pad to block multiples (interpret mode NaN-fills OOB block reads)."""
    n, c = x.shape
    pn = (-n) % br
    pc = (-c) % bc
    if pn == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pn), (0, pc)), constant_values=value)


def _cast_kernel(x_ref, inv_scale_ref, o_ref, *, spec: Fp8Spec):
    x = x_ref[...]
    inv = inv_scale_ref[0]
    o_ref[...] = encode_rne(x * inv, spec)


def quantize_per_tensor(x, scale, spec: Fp8Spec):
    """Q(x / scale) -> uint8 codes, per-tensor scalar scale."""
    n, c = x.shape
    bn = min(BLOCK_ROWS, n)
    bc = min(BLOCK_COLS, c)
    x = _pad2(x, bn, bc)
    np_, cp = x.shape
    grid = (pl.cdiv(np_, bn), pl.cdiv(cp, bc))
    inv = jnp.reshape(1.0 / jnp.asarray(scale, jnp.float32), (1,))
    return pl.pallas_call(
        functools.partial(_cast_kernel, spec=spec),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bc), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, cp), jnp.uint8),
        interpret=True,
    )(x, inv)[:n, :c]


def _cast_kernel_per_row(x_ref, inv_scale_ref, o_ref, *, spec: Fp8Spec):
    x = x_ref[...]
    inv = inv_scale_ref[...]  # (block_rows,)
    o_ref[...] = encode_rne(x * inv[:, None], spec)


def quantize_per_row(x, scales, spec: Fp8Spec):
    """Q(diag(s)^-1 x) -> uint8 codes, one scale per row (per-sample)."""
    n, c = x.shape
    bn = min(BLOCK_ROWS, n)
    bc = min(BLOCK_COLS, c)
    x = _pad2(x, bn, bc)
    np_, cp = x.shape
    grid = (pl.cdiv(np_, bn), pl.cdiv(cp, bc))
    inv = (1.0 / jnp.asarray(scales, jnp.float32)).astype(jnp.float32)
    inv = jnp.pad(inv, (0, np_ - n), constant_values=1.0)
    return pl.pallas_call(
        functools.partial(_cast_kernel_per_row, spec=spec),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bc), lambda i, j: (i, j)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((bn, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, cp), jnp.uint8),
        interpret=True,
    )(x, inv)[:n, :c]


def _dequant_kernel(codes_ref, scale_ref, o_ref, *, spec: Fp8Spec):
    from .fp8_jnp import decode

    o_ref[...] = decode(codes_ref[...], spec) * scale_ref[0]


def dequantize_per_tensor(codes, scale, spec: Fp8Spec):
    """codes -> f32 values × scale (the inverse stream)."""
    n, c = codes.shape
    bn = min(BLOCK_ROWS, n)
    bc = min(BLOCK_COLS, c)
    codes = _pad2(codes, bn, bc)
    np_, cp = codes.shape
    grid = (pl.cdiv(np_, bn), pl.cdiv(cp, bc))
    s = jnp.reshape(jnp.asarray(scale, jnp.float32), (1,))
    return pl.pallas_call(
        functools.partial(_dequant_kernel, spec=spec),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bc), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, cp), jnp.float32),
        interpret=True,
    )(codes, s)[:n, :c]
