"""Jittable FP8 emulation (JAX) — mirrors rust/src/fp8/ bit-for-bit.

Formats (paper §2, §2.4):
  * e4m3_gaudi2 — IEEE-style E4M3, top exponent reserved, range ±240
  * e4m3        — Gaudi 3 / OCP E4M3, range ±448
  * e5m2        — IEEE-style E5M2, range ±57344

Encode is round-to-nearest-even with saturating cast (the Gaudi inference
cast), implemented with the same integer tricks as the Rust encoder so the
two sides agree on every value. Codes are uint8.
"""

from dataclasses import dataclass
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Fp8Spec:
    name: str
    exp_bits: int
    man_bits: int
    bias: int
    ieee_reserved_top_exp: bool
    max_normal: float
    max_code: int
    nan_code: int

    @property
    def r_q(self) -> float:
        """The paper's r_q: largest representable magnitude."""
        return self.max_normal

    @property
    def min_normal_exp(self) -> int:
        return 1 - self.bias


E4M3_GAUDI2 = Fp8Spec("e4m3_gaudi2", 4, 3, 7, True, 240.0, 0x77, 0x7F)
E4M3 = Fp8Spec("e4m3", 4, 3, 7, False, 448.0, 0x7E, 0x7F)
E5M2 = Fp8Spec("e5m2", 5, 2, 15, True, 57344.0, 0x7B, 0x7F)

FORMATS = {s.name: s for s in (E4M3_GAUDI2, E4M3, E5M2)}


@lru_cache(maxsize=None)
def decode_table_np(spec: Fp8Spec) -> np.ndarray:
    """Exact 256-entry decode table (float32). NaN/Inf entries included."""
    out = np.zeros(256, dtype=np.float32)
    exp_mask = (1 << spec.exp_bits) - 1
    man_mask = (1 << spec.man_bits) - 1
    for code in range(256):
        sign = -1.0 if code & 0x80 else 1.0
        exp = (code >> spec.man_bits) & exp_mask
        man = code & man_mask
        if exp == exp_mask and spec.ieee_reserved_top_exp:
            out[code] = sign * (np.inf if man == 0 else np.nan)
            continue
        if exp == exp_mask and not spec.ieee_reserved_top_exp and man == man_mask:
            out[code] = np.nan
            continue
        if exp == 0:
            val = man * 2.0 ** (spec.min_normal_exp - spec.man_bits)
        else:
            val = (1.0 + man / (1 << spec.man_bits)) * 2.0 ** (exp - spec.bias)
        out[code] = sign * val
    return out


def decode(codes, spec: Fp8Spec):
    """uint8/uint32 codes -> float32, branchless bit assembly (jittable).

    NO gather: xla_extension 0.5.1 (the version the rust `xla` crate loads
    artifacts with) mis-executes jax≥0.8-emitted gather ops, so the decode
    table must never appear in artifact HLO. This also mirrors the hardware
    more closely — the MME consumes FP8 natively, there is no LUT.
    """
    c = codes.astype(jnp.uint32)
    m = spec.man_bits
    emask = jnp.uint32((1 << spec.exp_bits) - 1)
    mmask = jnp.uint32((1 << m) - 1)
    exp = (c >> m) & emask
    man = c & mmask
    neg = (c & jnp.uint32(0x80)) != 0
    sign_f = jnp.where(neg, jnp.float32(-1.0), jnp.float32(1.0))

    # Normal numbers: assemble the f32 bit pattern directly.
    nb = (
        ((c & jnp.uint32(0x80)) << 24)
        | ((exp + jnp.uint32(127 - spec.bias)) << 23)
        | (man << (23 - m))
    )
    normal = jax.lax.bitcast_convert_type(nb, jnp.float32)

    # Subnormals: value = man · 2^(1-bias-m). float(man) via the 2^23 trick
    # (man < 2^m ≤ 8, exact), avoiding an integer convert.
    manf = (
        jax.lax.bitcast_convert_type(jnp.uint32(0x4B000000) | man, jnp.float32)
        - jnp.float32(8388608.0)
    )
    sub = sign_f * manf * np.float32(2.0 ** (spec.min_normal_exp - m))

    out = jnp.where(exp == 0, sub, normal)

    # Specials.
    if spec.ieee_reserved_top_exp:
        inf = sign_f * jnp.float32(np.inf)
        out = jnp.where(exp == emask, jnp.where(man == 0, inf, jnp.float32(np.nan)), out)
    else:
        out = jnp.where((exp == emask) & (man == mmask), jnp.float32(np.nan), out)
    return out


def encode_rne(x, spec: Fp8Spec):
    """float32 -> uint8 codes, RNE + saturating (SatFinite) cast. Jittable.

    Identical algorithm to rust/src/fp8/encode.rs::encode_rne.
    """
    x = jnp.asarray(x, jnp.float32)
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    sign = ((bits >> 31) << 7).astype(jnp.uint32)
    abs_bits = bits & jnp.uint32(0x7FFFFFFF)

    m = spec.man_bits
    shift = 23 - m
    min_norm_exp = spec.min_normal_exp

    # --- normal path: RNE on the f32 mantissa (add-half trick) ------------
    lsb = (abs_bits >> shift) & 1
    rounded = abs_bits + jnp.uint32((1 << (shift - 1)) - 1) + lsb
    r_exp = (rounded >> 23).astype(jnp.int32) - 127
    r_man = (rounded >> shift) & jnp.uint32((1 << m) - 1)
    max_exp = (spec.max_code >> m) - spec.bias
    max_man = spec.max_code & ((1 << m) - 1)
    overflow = (r_exp > max_exp) | ((r_exp == max_exp) & (r_man > max_man))
    code_exp = (r_exp + spec.bias).astype(jnp.uint32)
    normal_code = (code_exp << m) | r_man
    normal_code = jnp.where(overflow, jnp.uint32(spec.max_code), normal_code)

    # --- subnormal path ----------------------------------------------------
    x_abs = jnp.abs(x)
    scaled = x_abs * np.float32(2.0 ** (m - min_norm_exp))
    # round-half-even on the scaled magnitude
    q = jnp.round(scaled).astype(jnp.uint32)  # jnp.round is ties-to-even
    sub_code = q  # q == 2^m lands exactly on the min normal code

    e_unb = (abs_bits >> 23).astype(jnp.int32) - 127
    is_sub = e_unb < min_norm_exp
    code = jnp.where(is_sub, sub_code, normal_code)

    # --- specials -----------------------------------------------------------
    is_nan = abs_bits > jnp.uint32(0x7F800000)
    is_inf = abs_bits == jnp.uint32(0x7F800000)
    is_zero = abs_bits == 0
    code = jnp.where(is_inf, jnp.uint32(spec.max_code), code)  # saturate inf
    code = jnp.where(is_nan, jnp.uint32(spec.nan_code), code)
    code = jnp.where(is_zero, jnp.uint32(0), code)

    return (sign | code).astype(jnp.uint8)


def fake_quant(x, spec: Fp8Spec):
    """decode(encode(x)): project onto the FP8 grid, staying in f32."""
    return decode(encode_rne(x, spec), spec)
