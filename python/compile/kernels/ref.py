"""Pure-numpy correctness oracles for the FP8 kernels.

`encode_nearest_oracle` is correct *by definition*: nearest representable
value by table search, exact ties to the even mantissa code — the same
oracle used on the Rust side (rust/src/fp8/encode.rs). The Pallas kernels
and the jittable bit-twiddling encoder are tested against it.
"""

import numpy as np

from .fp8_jnp import Fp8Spec, decode_table_np


def _sorted_positive(spec: Fp8Spec):
    table = decode_table_np(spec)
    vals, codes = [], []
    for c in range(128):  # positive codes only
        v = table[c]
        if np.isfinite(v):
            vals.append(v)
            codes.append(c)
    order = np.argsort(np.array(vals), kind="stable")
    return np.array(vals)[order], np.array(codes)[order]


def encode_nearest_oracle(x: np.ndarray, spec: Fp8Spec) -> np.ndarray:
    """RNE + saturating cast by exhaustive nearest search (slow, exact)."""
    vals, codes = _sorted_positive(spec)
    x = np.asarray(x, np.float32)
    out = np.zeros(x.shape, dtype=np.uint8)
    flat = x.ravel()
    res = out.ravel()
    for i, v in enumerate(flat):
        if np.isnan(v):
            res[i] = spec.nan_code | (0x80 if np.signbit(v) else 0)
            continue
        sign = 0x80 if (v < 0 or (v == 0 and np.signbit(v))) else 0
        a = abs(v)
        if a >= vals[-1]:
            res[i] = sign | spec.max_code
            continue
        j = int(np.searchsorted(vals, a))
        best_code, best_d = None, None
        for k in (j - 1, j):
            if 0 <= k < len(vals):
                d = abs(vals[k] - a)
                if best_d is None or d < best_d:
                    best_d, best_code = d, codes[k]
                elif d == best_d and codes[k] % 2 == 0:
                    best_code = codes[k]
        res[i] = sign | best_code
    return out


def quantize_ref(x: np.ndarray, scale, spec: Fp8Spec) -> np.ndarray:
    """Q(x / scale) — reference quantization (scale scalar or per-row)."""
    scale = np.asarray(scale, dtype=np.float32)
    if scale.ndim == 1:
        scale = scale[:, None]
    return encode_nearest_oracle(np.asarray(x, np.float32) / scale, spec)


def scaled_matmul_ref(x, w, s_x, s_w, spec: Fp8Spec) -> np.ndarray:
    """Eq. 2 reference: out = S_x (Q(S_x^-1 X) ⊗ Q(S_w^-1 W)^T) S_w.

    x: (N, C) float32 activations; w: (K, C) float32 weights.
    s_x: scalar or (N,); s_w: scalar or (K,). f32 accumulation.
    """
    table = decode_table_np(spec)
    xq = table[quantize_ref(x, s_x, spec)]
    wq = table[quantize_ref(w, s_w, spec)]
    acc = xq.astype(np.float32) @ wq.astype(np.float32).T
    s_x = np.asarray(s_x, np.float32)
    s_w = np.asarray(s_w, np.float32)
    sx_col = s_x[:, None] if s_x.ndim == 1 else s_x
    sw_row = s_w[None, :] if s_w.ndim == 1 else s_w
    return (acc * sx_col * sw_row).astype(np.float32)


def per_tensor_scale_ref(x, spec: Fp8Spec, backoff: float = 1.0) -> float:
    """Eq. 15a."""
    x = np.asarray(x)
    r = float(np.max(np.abs(x))) if x.size else 0.0
    s = r / (backoff * spec.r_q)
    return s if (s > 0 and np.isfinite(s)) else 1.0


def per_row_scale_ref(x, spec: Fp8Spec, backoff: float = 1.0) -> np.ndarray:
    """Eq. 17a / Eq. 20a (rows of x)."""
    r = np.max(np.abs(np.asarray(x)), axis=1)
    s = r / (backoff * spec.r_q)
    return np.where((s > 0) & np.isfinite(s), s, 1.0).astype(np.float32)
