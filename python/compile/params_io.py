"""Weights file writer — format shared with rust/src/runtime/params.rs.

Layout (little-endian):
  magic  b"GFP8PARM"
  u32    version (1)
  u32    tensor count
  repeat:
    u16  name length, name bytes (utf-8)
    u8   dtype (0 = f32, 1 = bf16-as-u16)
    u8   ndim
    u32×ndim dims
    data (f32 LE or u16 LE)
"""

import struct
from typing import Dict, List

import numpy as np

MAGIC = b"GFP8PARM"


def _to_bf16_u16(x: np.ndarray) -> np.ndarray:
    """Round-to-nearest-even f32 → bf16 bit pattern (u16)."""
    bits = x.astype(np.float32).view(np.uint32)
    lsb = (bits >> 16) & 1
    rounded = bits + np.uint32(0x7FFF) + lsb
    out = (rounded >> 16).astype(np.uint16)
    nan = np.isnan(x)
    if nan.any():
        out = np.where(nan, ((bits >> 16) | 0x0040).astype(np.uint16), out)
    return out


def save_params(path: str, tensors: Dict[str, np.ndarray], order: List[str], dtype="f32"):
    """Write tensors in `order` (the artifact argument order)."""
    assert dtype in ("f32", "bf16")
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", 1, len(order)))
        for name in order:
            arr = np.asarray(tensors[name], np.float32)
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            tag = 0 if dtype == "f32" else 1
            f.write(struct.pack("<BB", tag, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            if dtype == "f32":
                f.write(arr.astype("<f4").tobytes())
            else:
                f.write(_to_bf16_u16(arr).astype("<u2").tobytes())


def load_params(path: str) -> Dict[str, np.ndarray]:
    """Read back (for tests)."""
    out: Dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        assert f.read(8) == MAGIC, "bad magic"
        version, count = struct.unpack("<II", f.read(8))
        assert version == 1
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode()
            tag, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            numel = int(np.prod(dims)) if ndim else 1
            if tag == 0:
                data = np.frombuffer(f.read(4 * numel), "<f4").reshape(dims)
            else:
                raw = np.frombuffer(f.read(2 * numel), "<u2").astype(np.uint32)
                data = (raw << 16).view(np.float32).reshape(dims)
            out[name] = data.copy()
    return out
