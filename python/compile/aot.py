"""AOT compile path: train → calibrate → quantize → lower → artifacts/.

Emits HLO *text* (never `.serialize()` — the image's xla_extension 0.5.1
rejects jax ≥ 0.5's 64-bit-id protos; the text parser reassigns ids; see
/opt/xla-example/README.md), plus the weights file, calibration scales,
training loss curve, and a meta.json manifest the Rust runtime reads.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
        [--steps 300] [--fast] [--model tiny]

Runs ONCE at `make artifacts`; never on the request path.
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import params_io
from .train import synthetic_corpus, train_byte_lm

PREFILL_SEQS = (16, 32, 64, 128)
DECODE_BATCHES = (1, 2, 4, 8)
CACHE_T = 160
PREFILL_VARIANTS = ("bf16", "unit", "fp8_pt", "fp8_pc", "fp8_dyn")
DECODE_VARIANTS = ("bf16", "fp8_pt", "fp8_pc")
GEMM_SHAPE = (64, 256, 256)  # (M, K, N) operator artifact

# Paged decode ABI (ISSUE 5): block granularity mirrors the Rust
# `quant::KV_BLOCK_TOKENS`, and the compiled pool holds the largest decode
# batch's full windows twice over — headroom for the engine's prefix-cache
# over-provisioning (the engine validates its pool fits at startup).
PAGED_BLOCK_TOKENS = 16
PAGED_MAX_BLOCKS_PER_SEQ = -(-CACHE_T // PAGED_BLOCK_TOKENS)
PAGED_POOL_BLOCKS = 2 * max(DECODE_BATCHES) * PAGED_MAX_BLOCKS_PER_SEQ


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_prefill(cfg, names, qc, batch, seq):
    def fn(params_list, tokens):
        params = dict(zip(names, params_list))
        logits, kvs = M.prefill(params, tokens, cfg, qc)
        k, v = M.prefill_to_cache(kvs, cfg, max_seq=CACHE_T)
        return (logits, k, v)

    spec_params = [
        jax.ShapeDtypeStruct(M.param_shape(cfg, n), jnp.float32) for n in names
    ]
    spec_tokens = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    return jax.jit(fn).lower(spec_params, spec_tokens)


def lower_decode(cfg, names, qc, batch):
    kv_shape = M.kv_cache_shape(cfg, batch, CACHE_T)

    def fn(params_list, token, k_cache, v_cache, pos):
        params = dict(zip(names, params_list))
        return M.decode_step(params, token, k_cache, v_cache, pos, cfg, qc)

    spec_params = [
        jax.ShapeDtypeStruct(M.param_shape(cfg, n), jnp.float32) for n in names
    ]
    return jax.jit(fn).lower(
        spec_params,
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct(kv_shape, jnp.float32),
        jax.ShapeDtypeStruct(kv_shape, jnp.float32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),  # per-row positions
    )


def lower_decode_paged(cfg, names, qc, batch):
    """Block-table-native decode: the artifact takes the physical block
    pool plus per-row block tables/lengths and returns logits + only the
    appended token's KV — no dense (L, B, T, ...) cache round-trip."""
    pool_shape = (
        PAGED_POOL_BLOCKS,
        cfg.layers,
        PAGED_BLOCK_TOKENS,
        cfg.kv_heads,
        cfg.head_dim,
    )

    def fn(params_list, token, k_pool, v_pool, tables, lens):
        params = dict(zip(names, params_list))
        return M.decode_step_paged(params, token, k_pool, v_pool, tables, lens, cfg, qc)

    spec_params = [
        jax.ShapeDtypeStruct(M.param_shape(cfg, n), jnp.float32) for n in names
    ]
    return jax.jit(fn).lower(
        spec_params,
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct(pool_shape, jnp.float32),
        jax.ShapeDtypeStruct(pool_shape, jnp.float32),
        jax.ShapeDtypeStruct((batch, PAGED_MAX_BLOCKS_PER_SEQ), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),  # per-row valid lengths
    )


def gemm_fn(variant):
    """Standalone scaled-GEMM operator: (x f32[M,K], w f32[N,K]) → f32[M,N].

    Self-contained Eq. 2 with in-graph (JiT, §2.3.2) per-tensor activation
    scaling and per-tensor ('fp8_pt') or per-output-channel ('fp8_pc')
    weight scaling; 'unit' uses scale 1 everywhere. The Rust integration
    test compares this against the native `gemm` crate bit-for-bit-ish
    (f32 accumulation order differs across tilings)."""
    from .kernels import fp8_jnp as F
    from .kernels.scaled_matmul import fused_quant_matmul_fp8

    spec = F.E4M3_GAUDI2

    def fn(x, w):
        if variant == "bf16":
            return (x @ w.T,)
        m = x.shape[0]
        n = w.shape[0]
        if variant == "unit":
            s_x = jnp.ones((m,), jnp.float32)
            s_w = jnp.ones((n,), jnp.float32)
        else:
            r_x = jnp.max(jnp.abs(x))
            s = jnp.where((r_x > 0) & jnp.isfinite(r_x), r_x / spec.r_q, 1.0)
            s_x = jnp.full((m,), s)
            if variant == "fp8_pc":
                r_w = jnp.max(jnp.abs(w), axis=1)
            else:  # fp8_pt
                r_w = jnp.broadcast_to(jnp.max(jnp.abs(w)), (n,))
            s_w = jnp.where((r_w > 0) & jnp.isfinite(r_w), r_w / spec.r_q, 1.0)
        wq = F.encode_rne(w / s_w[:, None], spec)
        return (fused_quant_matmul_fp8(x, wq, s_x, s_w, spec),)

    return fn


def lower_gemm(variant, m, k, n):
    return jax.jit(gemm_fn(variant)).lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((n, k), jnp.float32),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--model", default="tiny", choices=list(M.CONFIGS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--fast", action="store_true", help="skip training (random weights)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    cfg = M.CONFIGS[args.model](vocab=256)  # byte-level
    names = M.param_names(cfg)
    t_start = time.time()

    # ---- 1. weights: train the byte-LM (or random-init with --fast) -------
    if args.fast:
        print("[aot] --fast: random-init weights")
        params = {k: jnp.asarray(v) for k, v in M.init_params(cfg, 0).items()}
        curve = []
    else:
        print(f"[aot] training byte-LM ({args.steps} steps)")
        params, curve = train_byte_lm(cfg, steps=args.steps)

    params_np = {k: np.asarray(v) for k, v in params.items()}
    params_io.save_params(os.path.join(args.out_dir, "weights_tiny.bin"), params_np, names)
    with open(os.path.join(args.out_dir, "loss_curve.json"), "w") as f:
        json.dump({"steps": [s for s, _ in curve], "loss": [l for _, l in curve]}, f)

    # ---- 2. calibration (§3.1) on held-out corpus --------------------------
    print("[aot] calibrating")
    calib_data = synthetic_corpus(n_chars=20_000, seed=99)  # disjoint seed
    cal_batches = [
        jnp.asarray(calib_data[i * 64 : i * 64 + 64].reshape(1, 64), jnp.int32)
        for i in range(4)
    ]
    scales = M.calibrate(params, cal_batches, cfg, M.F.E4M3_GAUDI2)
    with open(os.path.join(args.out_dir, "scales_tiny.json"), "w") as f:
        json.dump(scales, f, indent=2)
    print("[aot] act scales:", {k: round(v, 5) for k, v in scales.items()})

    # ---- 3. lower all artifacts --------------------------------------------
    artifacts = []

    def emit(name, lowered):
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        artifacts.append(name)
        print(f"[aot] wrote {name} ({len(text)//1024} KiB, {time.time()-t_start:.0f}s)")

    for variant in PREFILL_VARIANTS:
        qc = M.make_quant_config(variant, scales)
        for seq in PREFILL_SEQS:
            emit(
                f"prefill_{variant}_b1_s{seq}.hlo.txt",
                lower_prefill(cfg, names, qc, 1, seq),
            )

    for variant in DECODE_VARIANTS:
        qc = M.make_quant_config(variant, scales)
        for batch in DECODE_BATCHES:
            emit(f"decode_{variant}_b{batch}.hlo.txt", lower_decode(cfg, names, qc, batch))
            emit(
                f"decode_paged_{variant}_b{batch}.hlo.txt",
                lower_decode_paged(cfg, names, qc, batch),
            )

    m, k, n = GEMM_SHAPE
    for variant in ("bf16", "fp8_pt", "fp8_pc", "unit"):
        emit(f"gemm_{variant}.hlo.txt", lower_gemm(variant, m, k, n))

    # ---- 3b. cross-language selfcheck --------------------------------------
    # Expected outputs computed in python for fixed inputs; the Rust
    # integration suite reruns the artifacts and compares.
    print("[aot] computing selfcheck expectations")
    check_tokens = calib_data[:16].reshape(1, 16).astype(np.int32)
    selfcheck = {"tokens": check_tokens.ravel().tolist(), "prefill": {}, "gemm": {}}
    for variant in PREFILL_VARIANTS:
        qc = M.make_quant_config(variant, scales)
        logits, _ = M.prefill(params, jnp.asarray(check_tokens), cfg, qc)
        lg = np.asarray(logits)
        selfcheck["prefill"][variant] = {
            "first16": lg.ravel()[:16].tolist(),
            "l2": float(np.linalg.norm(lg.ravel())),
            "shape": list(lg.shape),
        }
    rng = np.random.default_rng(7)
    gx = (rng.standard_normal((m, k)) * 2).astype(np.float32)
    gw = (rng.standard_normal((n, k)) * 0.05).astype(np.float32)
    np.save(os.path.join(args.out_dir, "gemm_x.npy"), gx)
    np.save(os.path.join(args.out_dir, "gemm_w.npy"), gw)
    gx.tofile(os.path.join(args.out_dir, "gemm_x.f32"))
    gw.tofile(os.path.join(args.out_dir, "gemm_w.f32"))
    for variant in ("bf16", "fp8_pt", "fp8_pc", "unit"):
        out = np.asarray(gemm_fn(variant)(jnp.asarray(gx), jnp.asarray(gw))[0])
        selfcheck["gemm"][variant] = {
            "first16": out.ravel()[:16].tolist(),
            "l2": float(np.linalg.norm(out.ravel())),
        }
    with open(os.path.join(args.out_dir, "selfcheck.json"), "w") as f:
        json.dump(selfcheck, f, indent=2)

    # ---- 4. manifest --------------------------------------------------------
    meta = {
        "model": {
            "name": cfg.name,
            "vocab": cfg.vocab,
            "hidden": cfg.hidden,
            "layers": cfg.layers,
            "heads": cfg.heads,
            "kv_heads": cfg.kv_heads,
            "ffn_hidden": cfg.ffn_hidden,
        },
        "param_order": names,
        "param_shapes": {n_: list(M.param_shape(cfg, n_)) for n_ in names},
        "cache_t": CACHE_T,
        "paged_block_tokens": PAGED_BLOCK_TOKENS,
        "paged_pool_blocks": PAGED_POOL_BLOCKS,
        "prefill_seqs": list(PREFILL_SEQS),
        "decode_batches": list(DECODE_BATCHES),
        "prefill_variants": list(PREFILL_VARIANTS),
        "decode_variants": list(DECODE_VARIANTS),
        "gemm_shape": list(GEMM_SHAPE),
        "act_scales": scales,
        "artifacts": artifacts,
    }
    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"[aot] DONE: {len(artifacts)} artifacts in {time.time()-t_start:.0f}s")


if __name__ == "__main__":
    main()
