"""Build-time training of the byte-level LM used by the e2e serving demo.

A tiny synthetic corpus (structured pseudo-text with strong n-gram
statistics) is generated deterministically; the tiny model is trained with
Adam for a few hundred steps so the served model produces a real, falling
loss curve and non-degenerate generations. Runs once inside `make
artifacts`; never on the request path.
"""

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M


def synthetic_corpus(n_chars: int = 200_000, seed: int = 0) -> np.ndarray:
    """Pseudo-text over a 96-symbol alphabet with word/sentence structure:
    zipfian words from a fixed vocabulary, spaces and punctuation — enough
    statistical structure for a byte-LM to learn something measurable."""
    rng = np.random.default_rng(seed)
    n_words = 800
    word_lens = rng.integers(2, 9, n_words)
    words = [
        bytes(rng.integers(ord("a"), ord("z") + 1, wl).astype(np.uint8)).decode()
        for wl in word_lens
    ]
    # Zipfian frequencies.
    ranks = np.arange(1, n_words + 1)
    probs = (1.0 / ranks) / np.sum(1.0 / ranks)
    chunks: List[str] = []
    total = 0
    while total < n_chars:
        sent_len = int(rng.integers(4, 13))
        ws = rng.choice(n_words, sent_len, p=probs)
        sent = " ".join(words[int(w)] for w in ws)
        sent = sent.capitalize() + ". "
        chunks.append(sent)
        total += len(sent)
    text = "".join(chunks)[:n_chars]
    data = np.frombuffer(text.encode(), np.uint8).astype(np.int32)
    return data


def batches(data: np.ndarray, batch: int, seq: int, steps: int, seed: int = 1):
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        starts = rng.integers(0, len(data) - seq - 1, batch)
        x = np.stack([data[s : s + seq] for s in starts])
        y = np.stack([data[s + 1 : s + seq + 1] for s in starts])
        yield jnp.asarray(x), jnp.asarray(y)


def loss_fn(params, x, y, cfg):
    qc = M.QuantConfig(variant="bf16")
    logits, _ = M.prefill(params, x, cfg, qc)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)
    return jnp.mean(nll)


def train_byte_lm(
    cfg: M.ModelConfig,
    steps: int = 300,
    batch: int = 16,
    seq: int = 64,
    lr: float = 3e-3,
    seed: int = 0,
    log_every: int = 25,
) -> Tuple[Dict[str, jnp.ndarray], List[Tuple[int, float]]]:
    """Returns (params, loss_curve). cfg.vocab must be ≥ 256."""
    assert cfg.vocab >= 256
    data = synthetic_corpus(seed=seed)
    params = {k: jnp.asarray(v) for k, v in M.init_params(cfg, seed).items()}

    # Adam state.
    mu = jax.tree.map(jnp.zeros_like, params)
    nu = jax.tree.map(jnp.zeros_like, params)
    b1, b2, eps = 0.9, 0.999, 1e-8

    @jax.jit
    def step(params, mu, nu, x, y, t):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(p, x, y, cfg))(params)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, mu, grads)
        nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * g * g, nu, grads)
        t = t.astype(jnp.float32)
        mhat = jax.tree.map(lambda m: m / (1 - b1**t), mu)
        nhat = jax.tree.map(lambda n: n / (1 - b2**t), nu)
        params = jax.tree.map(
            lambda p, m, n: p - lr * m / (jnp.sqrt(n) + eps), params, mhat, nhat
        )
        return params, mu, nu, loss

    curve: List[Tuple[int, float]] = []
    t0 = time.time()
    for i, (x, y) in enumerate(batches(data, batch, seq, steps, seed + 1), start=1):
        params, mu, nu, loss = step(params, mu, nu, x, y, jnp.asarray(i))
        if i % log_every == 0 or i == 1:
            curve.append((i, float(loss)))
            print(f"  step {i:4d} loss {float(loss):.4f} ({time.time()-t0:.0f}s)")
    return params, curve
