"""L2: Llama-style decoder-only transformer in pure JAX with quantized
linears (Eq. 2), mirroring rust/src/model/config.rs geometry.

Build-time only: this module is lowered once by `aot.py` to HLO text; the
Rust coordinator executes the compiled artifacts. Nothing here runs on the
request path.

Quantization variants (the paper's Tables 2–4 grid):
  * ``bf16``      — high-precision reference;
  * ``unit``      — FP8 with all scales = 1;
  * ``fp8_pt``    — static per-tensor activation scales (Eq. 15) +
                    per-tensor weight scales (Eq. 18);
  * ``fp8_pc``    — static per-tensor activations + per-output-channel
                    weight scales (Eq. 20);
  * ``fp8_dyn``   — dynamic (JiT) per-sample activation scales (Eq. 17).

Attention and the LM head stay high-precision (§4.2.4, Table 5 caption).
"""

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import fp8_jnp as F
from .kernels.scaled_matmul import fused_quant_matmul_fp8

VARIANTS = ("bf16", "unit", "fp8_pt", "fp8_pc", "fp8_dyn")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    hidden: int
    layers: int
    heads: int
    kv_heads: int
    ffn_hidden: int
    max_seq: int = 256

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads


def tiny_config(vocab: int = 512) -> ModelConfig:
    """~3.5M-parameter Llama-style model — the e2e serving model."""
    return ModelConfig("syn-tiny", vocab, 256, 4, 8, 2, 704)


def small_config(vocab: int = 512) -> ModelConfig:
    return ModelConfig("syn-small", vocab, 448, 6, 8, 2, 1216)


def base_config(vocab: int = 512) -> ModelConfig:
    """~100M-parameter analogue (the '70B-class' stand-in)."""
    return ModelConfig("syn-base", vocab, 768, 12, 12, 4, 2048)


CONFIGS = {"tiny": tiny_config, "small": small_config, "base": base_config}


def param_names(cfg: ModelConfig) -> List[str]:
    """Flat deterministic parameter order — the Rust runtime marshals
    arguments by this order."""
    names = ["embed"]
    for i in range(cfg.layers):
        names += [
            f"l{i}.attn_norm",
            f"l{i}.wq",
            f"l{i}.wk",
            f"l{i}.wv",
            f"l{i}.wo",
            f"l{i}.mlp_norm",
            f"l{i}.gate",
            f"l{i}.up",
            f"l{i}.down",
        ]
    names += ["final_norm", "lm_head"]
    return names


def param_shape(cfg: ModelConfig, name: str) -> tuple:
    h, hd = cfg.hidden, cfg.head_dim
    if name in ("embed", "lm_head"):
        return (cfg.vocab, h)  # linears stored out×in
    if name.endswith("norm"):
        return (h,)
    key = name.split(".")[1]
    return {
        "wq": (cfg.heads * hd, h),
        "wk": (cfg.kv_heads * hd, h),
        "wv": (cfg.kv_heads * hd, h),
        "wo": (h, cfg.heads * hd),
        "gate": (cfg.ffn_hidden, h),
        "up": (cfg.ffn_hidden, h),
        "down": (h, cfg.ffn_hidden),
    }[key]


def param_count(cfg: ModelConfig) -> int:
    return sum(int(np.prod(param_shape(cfg, n))) for n in param_names(cfg))


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, np.ndarray]:
    """Scaled-normal init (numpy, so artifacts are reproducible)."""
    rng = np.random.default_rng(seed)
    params = {}
    for name in param_names(cfg):
        shape = param_shape(cfg, name)
        if name.endswith("norm"):
            params[name] = np.ones(shape, np.float32)
        else:
            fan_in = shape[-1]
            params[name] = (rng.standard_normal(shape) / np.sqrt(fan_in)).astype(
                np.float32
            )
    return params


# --------------------------------------------------------------------------
# Quantization config
# --------------------------------------------------------------------------

# lm_head/embed are never quantized (§3.3 step 5).
QUANT_SITES = ("wq", "wk", "wv", "wo", "gate", "up", "down")


@dataclass
class QuantConfig:
    variant: str = "bf16"
    spec: F.Fp8Spec = F.E4M3_GAUDI2
    backoff: float = 1.0
    # Static per-tensor activation scales per site kind, from calibration.
    act_scales: Dict[str, float] = field(default_factory=dict)

    def is_fp8(self) -> bool:
        return self.variant != "bf16"


def _weight_scales(w: jnp.ndarray, qc: QuantConfig) -> jnp.ndarray:
    """Per-row (out-channel) scale vector; per-tensor/unit broadcast."""
    k = w.shape[0]
    if qc.variant == "unit":
        return jnp.ones((k,), jnp.float32)
    if qc.variant == "fp8_pc":
        r = jnp.max(jnp.abs(w), axis=1)
        s = r / qc.spec.r_q
        return jnp.where((s > 0) & jnp.isfinite(s), s, 1.0)
    r = jnp.max(jnp.abs(w))
    s = r / qc.spec.r_q
    s = jnp.where((s > 0) & jnp.isfinite(s), s, 1.0)
    return jnp.full((k,), 1.0, jnp.float32) * s


def quant_linear(x: jnp.ndarray, w: jnp.ndarray, site: str, qc: QuantConfig) -> jnp.ndarray:
    """One linear `x @ w.T` under the active quantization config.

    x: (..., C); w: (K, C). Weight quantization happens in-graph on the f32
    master weights — numerically identical to offline quantization with the
    same (statically known) scales, and it keeps one weights file for all
    variants. XLA constant-folds none of it away since weights are runtime
    inputs; the cost is visible and measured in the operator benches.
    """
    if not qc.is_fp8():
        return x @ w.T

    lead = x.shape[:-1]
    c = x.shape[-1]
    x2 = x.reshape((-1, c))
    m = x2.shape[0]

    s_w = _weight_scales(w, qc)
    wq = F.encode_rne(w / s_w[:, None], qc.spec)

    if qc.variant == "unit":
        s_x = jnp.ones((m,), jnp.float32)
    elif qc.variant == "fp8_dyn":
        r = jnp.max(jnp.abs(x2), axis=1)
        s = r / (qc.backoff * qc.spec.r_q)
        s_x = jnp.where((s > 0) & jnp.isfinite(s), s, 1.0)
    else:  # static per-tensor from calibration
        s = qc.act_scales.get(site, 1.0)
        s_x = jnp.full((m,), jnp.float32(s))

    # L2 perf (EXPERIMENTS.md §Perf): the tiled Pallas kernel is the
    # hardware-shaped path and pays off at prefill sizes; at decode sizes
    # (M ≤ a few tokens) its grid loop is pure overhead on the CPU PJRT
    # backend — an M<64 GEMM occupies a single MME tile on Gaudi anyway, so
    # the dense Eq.-2 path (identical numerics: same casts, same f32
    # accumulation) is used below the threshold.
    if m >= 64:
        out = fused_quant_matmul_fp8(x2, wq, s_x, s_w, qc.spec)
    else:
        xf = F.decode(F.encode_rne(x2 / s_x[:, None], qc.spec), qc.spec)
        wf = F.decode(wq, qc.spec)
        acc = jax.lax.dot_general(
            xf, wf, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        out = acc * s_x[:, None] * s_w[None, :]
    return out.reshape(lead + (w.shape[0],))


# --------------------------------------------------------------------------
# Transformer
# --------------------------------------------------------------------------


def rms_norm(x, g, eps=1e-5):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def rope(x, positions, base: float = 10000.0):
    """x: (B, S, H, D). Rotary embedding on split halves."""
    d = x.shape[-1]
    half = d // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def attention(q, k, v, mask):
    """q: (B,S,H,D); k,v: (B,T,Hkv,D) — GQA by head repetition. Kept
    high-precision (out of FP8) per the paper."""
    d = q.shape[-1]
    rep = q.shape[2] // k.shape[2]
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bshd,bthd->bhst", q, k) / np.float32(np.sqrt(d))
    logits = jnp.where(mask, logits, jnp.float32(-1e30))
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def block(x, params, i, cfg: ModelConfig, qc: QuantConfig, positions, kv_prev, mask):
    """One decoder block. kv_prev: (k, v) of past keys (B,T,Hkv,D) or None.
    Returns (x, (k_new, v_new)) where k_new/v_new cover only this call's
    positions."""
    hd = cfg.head_dim
    b, s = x.shape[0], x.shape[1]
    xn = rms_norm(x, params[f"l{i}.attn_norm"])
    q = quant_linear(xn, params[f"l{i}.wq"], "wq", qc).reshape(b, s, cfg.heads, hd)
    k = quant_linear(xn, params[f"l{i}.wk"], "wk", qc).reshape(b, s, cfg.kv_heads, hd)
    v = quant_linear(xn, params[f"l{i}.wv"], "wv", qc).reshape(b, s, cfg.kv_heads, hd)
    q = rope(q, positions)
    k = rope(k, positions)
    if kv_prev is not None:
        k_all = jnp.concatenate([kv_prev[0], k], axis=1)
        v_all = jnp.concatenate([kv_prev[1], v], axis=1)
    else:
        k_all, v_all = k, v
    att = attention(q, k_all, v_all, mask).reshape(b, s, cfg.heads * hd)
    x = x + quant_linear(att, params[f"l{i}.wo"], "wo", qc)
    xn = rms_norm(x, params[f"l{i}.mlp_norm"])
    g = quant_linear(xn, params[f"l{i}.gate"], "gate", qc)
    u = quant_linear(xn, params[f"l{i}.up"], "up", qc)
    x = x + quant_linear(jax.nn.silu(g) * u, params[f"l{i}.down"], "down", qc)
    return x, (k, v)


def embed_lookup(embed, tokens):
    """Embedding via one-hot matmul — gather-free (the artifact-executing
    XLA 0.5.1 mis-executes jax-0.8 gather ops; see kernels/fp8_jnp.decode)."""
    onehot = jax.nn.one_hot(tokens, embed.shape[0], dtype=jnp.float32)
    return onehot @ embed


def prefill(params, tokens, cfg: ModelConfig, qc: QuantConfig):
    """tokens: (B, S) int32 → (logits (B,S,V), kvs: list of (k, v))."""
    b, s = tokens.shape
    x = embed_lookup(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    causal = jnp.tril(jnp.ones((s, s), bool))[None, None, :, :]
    kvs = []
    for i in range(cfg.layers):
        x, kv = block(x, params, i, cfg, qc, positions, None, causal)
        kvs.append(kv)
    x = rms_norm(x, params["final_norm"])
    logits = x @ params["lm_head"].T  # lm_head stays high-precision
    return logits, kvs


def decode_step(params, token, k_cache, v_cache, pos, cfg: ModelConfig, qc: QuantConfig):
    """One decode step with a static-shape cache and RAGGED positions —
    the continuous batcher mixes requests at different lengths.

    token: (B,) int32; k_cache/v_cache: (L, B, T, Hkv, D) f32; pos: (B,)
    int32 — per-row count of valid cache entries. Returns (logits (B, V),
    k_cache, v_cache) with each row's `pos[b]` slot written.

    The per-row cache write is an unrolled loop of dynamic_update_slice
    calls (B ≤ 8): scatter ops are out — the artifact-executing XLA 0.5.1
    mis-executes jax-0.8 gather/scatter.
    """
    b = token.shape[0]
    t = k_cache.shape[2]
    x = embed_lookup(params["embed"], token[:, None])  # (B, 1, H)
    positions = pos[:, None].astype(jnp.int32)  # (B, 1)
    idx = jnp.arange(t)
    # Keys: T cache slots (valid where slot < pos[b]) + self (always seen).
    valid = (idx[None, :] < pos[:, None])[:, None, None, :]  # (B,1,1,T)
    mask = jnp.concatenate([valid, jnp.ones((b, 1, 1, 1), bool)], axis=-1)
    new_k, new_v = [], []
    for i in range(cfg.layers):
        kv_prev = (k_cache[i], v_cache[i])
        x, kv = block(x, params, i, cfg, qc, positions, kv_prev, mask)
        new_k.append(kv[0])
        new_v.append(kv[1])
    x = rms_norm(x, params["final_norm"])
    logits = (x @ params["lm_head"].T)[:, 0, :]
    nk = jnp.stack(new_k, 0)  # (L, B, 1, Hkv, D)
    nv = jnp.stack(new_v, 0)
    for row in range(b):
        k_slice = jax.lax.dynamic_slice_in_dim(nk, row, 1, axis=1)
        v_slice = jax.lax.dynamic_slice_in_dim(nv, row, 1, axis=1)
        start = (0, row, pos[row], 0, 0)
        k_cache = jax.lax.dynamic_update_slice(k_cache, k_slice, start)
        v_cache = jax.lax.dynamic_update_slice(v_cache, v_slice, start)
    return logits, k_cache, v_cache


def decode_step_paged(params, token, k_pool, v_pool, tables, lens, cfg: ModelConfig, qc: QuantConfig):
    """Block-table-native decode step (vLLM-style paged-attention ABI).

    token: (B,) int32; k_pool/v_pool: (NB, L, BT, Hkv, D) f32 — the shared
    physical block pool, device-resident between steps; tables: (B, MB)
    int32 per-row block tables (entries past the live range may repeat a
    pad id — the validity mask hides them); lens: (B,) int32 valid counts.

    Returns (logits (B, V), new_k (L, B, 1, Hkv, D), new_v): only the
    appended token's KV leaves the graph — the host quantizes it into the
    row's hot block, so the dense cache round-trip of `decode_step` is
    gone and per-step KV traffic is the live block bytes.

    Block gathers use one-hot matmuls (gather-free: the artifact-executing
    XLA 0.5.1 mis-executes jax-0.8 gather/scatter ops); a real Gaudi
    paged-attention kernel instead walks the tables and reads the pool in
    place, dequantizing FP8 blocks on read.
    """
    b = token.shape[0]
    nb, l_, bt, hkv, d = k_pool.shape
    mb = tables.shape[1]
    t = mb * bt
    onehot = jax.nn.one_hot(tables, nb, dtype=jnp.float32)  # (B, MB, NB)
    kf = k_pool.reshape(nb, l_ * bt * hkv * d)
    vf = v_pool.reshape(nb, l_ * bt * hkv * d)
    kg = (onehot.reshape(b * mb, nb) @ kf).reshape(b, mb, l_, bt, hkv, d)
    vg = (onehot.reshape(b * mb, nb) @ vf).reshape(b, mb, l_, bt, hkv, d)
    # (B, MB, L, BT, Hkv, D) → (L, B, MB·BT, Hkv, D) per-layer context.
    kg = jnp.transpose(kg, (2, 0, 1, 3, 4, 5)).reshape(l_, b, t, hkv, d)
    vg = jnp.transpose(vg, (2, 0, 1, 3, 4, 5)).reshape(l_, b, t, hkv, d)

    x = embed_lookup(params["embed"], token[:, None])  # (B, 1, H)
    positions = lens[:, None].astype(jnp.int32)  # (B, 1)
    idx = jnp.arange(t)
    # Keys: T pooled positions (valid where pos < lens[b]) + self.
    valid = (idx[None, :] < lens[:, None])[:, None, None, :]  # (B,1,1,T)
    mask = jnp.concatenate([valid, jnp.ones((b, 1, 1, 1), bool)], axis=-1)
    new_k, new_v = [], []
    for i in range(cfg.layers):
        kv_prev = (kg[i], vg[i])
        x, kv = block(x, params, i, cfg, qc, positions, kv_prev, mask)
        new_k.append(kv[0])
        new_v.append(kv[1])
    x = rms_norm(x, params["final_norm"])
    logits = (x @ params["lm_head"].T)[:, 0, :]
    nk = jnp.stack(new_k, 0)  # (L, B, 1, Hkv, D)
    nv = jnp.stack(new_v, 0)
    return logits, nk, nv


def kv_cache_shape(cfg: ModelConfig, batch: int, max_seq: Optional[int] = None):
    t = max_seq or cfg.max_seq
    return (cfg.layers, batch, t, cfg.kv_heads, cfg.head_dim)


def prefill_to_cache(kvs, cfg: ModelConfig, max_seq: Optional[int] = None):
    """Stack prefill KV lists into the static cache layout (padded to T)."""
    t = max_seq or cfg.max_seq
    k = jnp.stack([kv[0] for kv in kvs], 0)  # (L, B, S, Hkv, D)
    v = jnp.stack([kv[1] for kv in kvs], 0)
    s = k.shape[2]
    pad = [(0, 0), (0, 0), (0, t - s), (0, 0), (0, 0)]
    return jnp.pad(k, pad), jnp.pad(v, pad)


# --------------------------------------------------------------------------
# Calibration (§3.1)
# --------------------------------------------------------------------------


def calibrate(params, token_batches, cfg: ModelConfig, spec: F.Fp8Spec, backoff=1.0):
    """Run calibration batches through the high-precision model, record
    per-site-kind r_x (Eq. 8a), return static per-tensor scales (Eq. 15a)."""
    site_max: Dict[str, float] = {s: 0.0 for s in QUANT_SITES}

    def record(site, value):
        site_max[site] = max(site_max[site], float(jnp.max(jnp.abs(value))))

    for tokens in token_batches:
        tokens = jnp.asarray(tokens, jnp.int32)
        b, s = tokens.shape
        x = embed_lookup(params["embed"], tokens)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        causal = jnp.tril(jnp.ones((s, s), bool))[None, None, :, :]
        for i in range(cfg.layers):
            hd = cfg.head_dim
            xn = rms_norm(x, params[f"l{i}.attn_norm"])
            record("wq", xn)
            record("wk", xn)
            record("wv", xn)
            q = (xn @ params[f"l{i}.wq"].T).reshape(b, s, cfg.heads, hd)
            k = (xn @ params[f"l{i}.wk"].T).reshape(b, s, cfg.kv_heads, hd)
            v = (xn @ params[f"l{i}.wv"].T).reshape(b, s, cfg.kv_heads, hd)
            q, k = rope(q, positions), rope(k, positions)
            att = attention(q, k, v, causal).reshape(b, s, cfg.heads * hd)
            record("wo", att)
            x = x + att @ params[f"l{i}.wo"].T
            xn = rms_norm(x, params[f"l{i}.mlp_norm"])
            record("gate", xn)
            record("up", xn)
            g = xn @ params[f"l{i}.gate"].T
            u = xn @ params[f"l{i}.up"].T
            act = jax.nn.silu(g) * u
            record("down", act)
            x = x + act @ params[f"l{i}.down"].T
    scales = {}
    for site, r in site_max.items():
        s = r / (backoff * spec.r_q)
        scales[site] = float(s) if (s > 0 and np.isfinite(s)) else 1.0
    return scales


def make_quant_config(variant: str, act_scales: Dict[str, float], spec=F.E4M3_GAUDI2):
    assert variant in VARIANTS, variant
    return QuantConfig(variant=variant, spec=spec, act_scales=dict(act_scales))
