//! CLI for the workspace lint pass.
//!
//! Usage: `cargo run -p repro-lint -- [--deny] [--json <file>]
//! [--schema <file>] <paths...>`
//!
//! Prints one `file:line: [rule] message` diagnostic per violation.
//! `--deny` makes violations fatal (exit 1); `--json` additionally writes
//! the diagnostics as a JSON array; `--schema` overrides the default
//! bench key schema (`tools/repro-lint/bench_schema.txt`, resolved
//! relative to the working directory — the workspace root when run via
//! `cargo run -p repro-lint`).

use std::env;
use std::fs;
use std::path::PathBuf;
use std::process::ExitCode;

use repro_lint::{diags_to_json, lint_paths, render_human, Schema};

const DEFAULT_SCHEMA: &str = "tools/repro-lint/bench_schema.txt";

fn print_help() {
    eprintln!(
        "repro-lint: static-analysis pass for the workspace's KV-bytes, \
         clock, and hot-path contracts\n\n\
         usage: repro-lint [--deny] [--json <file>] [--schema <file>] <paths...>\n\
         \n  --deny            exit non-zero when violations are found\
         \n  --json <file>     also write diagnostics as a JSON array\
         \n  --schema <file>   bench-json-schema key list (default: {DEFAULT_SCHEMA})"
    );
}

fn main() -> ExitCode {
    let mut deny = false;
    let mut json_out: Option<PathBuf> = None;
    let mut schema_path: Option<PathBuf> = None;
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut args = env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--deny" => deny = true,
            "--json" => match args.next() {
                Some(p) => json_out = Some(PathBuf::from(p)),
                None => {
                    eprintln!("repro-lint: --json requires a path");
                    return ExitCode::from(2);
                }
            },
            "--schema" => match args.next() {
                Some(p) => schema_path = Some(PathBuf::from(p)),
                None => {
                    eprintln!("repro-lint: --schema requires a path");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                print_help();
                return ExitCode::SUCCESS;
            }
            _ => paths.push(PathBuf::from(a)),
        }
    }
    if paths.is_empty() {
        print_help();
        return ExitCode::from(2);
    }

    let schema_file = schema_path.unwrap_or_else(|| PathBuf::from(DEFAULT_SCHEMA));
    let schema = if schema_file.exists() {
        match Schema::load(&schema_file) {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!(
                    "repro-lint: cannot read schema {}: {e}",
                    schema_file.display()
                );
                return ExitCode::from(2);
            }
        }
    } else {
        eprintln!(
            "repro-lint: no bench schema at {} — skipping bench-json-schema",
            schema_file.display()
        );
        None
    };

    let diags = match lint_paths(&paths, schema.as_ref()) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("repro-lint: {e}");
            return ExitCode::from(2);
        }
    };
    for d in &diags {
        println!("{}", render_human(d));
    }
    if let Some(p) = &json_out {
        if let Some(dir) = p.parent() {
            if !dir.as_os_str().is_empty() {
                let _ = fs::create_dir_all(dir);
            }
        }
        if let Err(e) = fs::write(p, diags_to_json(&diags)) {
            eprintln!("repro-lint: cannot write {}: {e}", p.display());
            return ExitCode::from(2);
        }
    }
    if diags.is_empty() {
        eprintln!("repro-lint: clean");
        ExitCode::SUCCESS
    } else {
        eprintln!("repro-lint: {} violation(s)", diags.len());
        if deny {
            ExitCode::FAILURE
        } else {
            ExitCode::SUCCESS
        }
    }
}
