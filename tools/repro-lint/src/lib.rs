//! `repro-lint` — a dependency-free static-analysis pass for this
//! workspace's cross-cutting contracts.
//!
//! The serving stack has three contracts nothing enforces mechanically:
//! KV byte accounting routes through `quant::KvLayout`, timing routes
//! through `obs::Clock` (so wall and virtual timelines export
//! identically), and the paged decode hot path stays allocation-free.
//! This crate lexes Rust source — comments, strings, char literals, and
//! `#[cfg(test)]` / `mod tests` regions correctly skipped — and runs five
//! rules over the token stream:
//!
//! - **clock-discipline**: no `std::time::Instant` / `SystemTime` outside
//!   `obs/`.
//! - **bytes-through-layout**: no `size_of` and no numeric-literal byte
//!   multiplications (inside `*byte*`-, `*swap*`-, and `*transfer*`-named
//!   functions — the host KV tier's swap/transfer paths move the same
//!   accounted bytes) outside `quant/` and `fp8/`.
//! - **hot-path-no-alloc**: no `Vec::new` / `vec!` / `.to_vec()` /
//!   `.clone()` / `.collect()` inside functions annotated with a
//!   `// lint: hot-path` comment.
//! - **no-unwrap-in-lib**: `.unwrap()` / `.expect(` / `panic!` in
//!   non-test library code must carry a *justified* pragma.
//! - **bench-json-schema**: string literals inside `*json_row*`-named
//!   functions may only name JSON keys declared in a checked-in schema
//!   list, so bench artifact keys cannot silently fork.
//!
//! Violations are silenced per line with `// lint:allow(<rule>): <why>`
//! (same line or the line directly above); `no-unwrap-in-lib` requires
//! the `: <why>` justification to be non-empty. Diagnostics render as
//! `file:line: [rule] message` and as a JSON array.

use std::collections::BTreeSet;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

pub const RULE_CLOCK: &str = "clock-discipline";
pub const RULE_BYTES: &str = "bytes-through-layout";
pub const RULE_HOT: &str = "hot-path-no-alloc";
pub const RULE_UNWRAP: &str = "no-unwrap-in-lib";
pub const RULE_JSON: &str = "bench-json-schema";

pub const ALL_RULES: [&str; 5] = [RULE_CLOCK, RULE_BYTES, RULE_HOT, RULE_UNWRAP, RULE_JSON];

/// One lexed token. Comments and whitespace never become tokens; string
/// literals keep their (unescaped) content so the bench-json-schema rule
/// can inspect emitted keys.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident(String),
    Num(String),
    Str(String),
    Punct(char),
}

#[derive(Clone, Debug)]
pub struct Tok {
    pub line: usize,
    pub kind: TokKind,
}

/// A `// lint:allow(rule)` or `// lint:allow(rule): why` pragma.
/// It silences matching diagnostics on its own line and the line below.
#[derive(Clone, Debug)]
pub struct Allow {
    pub line: usize,
    pub rule: String,
    pub justified: bool,
}

/// Lexer output: the token stream (test regions *not* yet stripped — see
/// [`strip_test_regions`]), the allow pragmas, and the lines carrying a
/// `// lint: hot-path` annotation.
#[derive(Clone, Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub allows: Vec<Allow>,
    pub hot_lines: Vec<usize>,
}

/// A function item found in the (test-stripped) token stream: its name,
/// the line of the `fn` keyword, the token-index span of its body braces
/// (inclusive of both `{` and `}`), and whether a `// lint: hot-path`
/// annotation precedes it.
#[derive(Clone, Debug)]
pub struct FnSpan {
    pub name: String,
    pub line: usize,
    pub body: (usize, usize),
    pub hot: bool,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diag {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

/// The checked-in list of JSON keys bench emitters may name.
pub struct Schema {
    keys: BTreeSet<String>,
}

impl Schema {
    pub fn load(path: &Path) -> io::Result<Schema> {
        Ok(Schema::from_lines(&fs::read_to_string(path)?))
    }

    /// One key per line; blank lines and `#` comments are ignored.
    pub fn from_lines(text: &str) -> Schema {
        let mut keys = BTreeSet::new();
        for raw in text.lines() {
            let k = raw.trim();
            if k.is_empty() || k.starts_with('#') {
                continue;
            }
            keys.insert(k.to_string());
        }
        Schema { keys }
    }

    pub fn contains(&self, key: &str) -> bool {
        self.keys.contains(key)
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }
}

fn ident_is(t: &Tok, s: &str) -> bool {
    matches!(&t.kind, TokKind::Ident(i) if i == s)
}

fn is_punct(t: &Tok, c: char) -> bool {
    t.kind == TokKind::Punct(c)
}

fn ident_at(toks: &[Tok], i: usize, s: &str) -> bool {
    toks.get(i).is_some_and(|t| ident_is(t, s))
}

fn punct_at(toks: &[Tok], i: usize, c: char) -> bool {
    toks.get(i).is_some_and(|t| is_punct(t, c))
}

/// Lex Rust source into a token stream, extracting lint pragmas and
/// hot-path annotations from comments along the way. Line comments,
/// nested block comments, normal/raw/byte string literals, char literals,
/// and lifetimes are all handled; doc comments (`///`, `//!`) are plain
/// comments to the lexer.
pub fn lex(src: &str) -> Lexed {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut i = 0usize;
    let mut line = 1usize;
    let mut out = Lexed::default();
    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment: scan for pragmas, consume to end of line.
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let start = i + 2;
            let mut j = start;
            while j < n && cs[j] != '\n' {
                j += 1;
            }
            let text: String = cs[start..j].iter().collect();
            scan_pragma(&text, line, &mut out);
            i = j;
            continue;
        }
        // Block comment, nesting.
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if cs[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if cs[i] == '/' && i + 1 < n && cs[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if cs[i] == '*' && i + 1 < n && cs[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // Byte string b"..." — lex like a normal string.
        if c == 'b' && i + 1 < n && cs[i + 1] == '"' {
            let tline = line;
            let (content, ni, nl) = lex_string(&cs, i + 1, line);
            out.toks.push(Tok {
                line: tline,
                kind: TokKind::Str(content),
            });
            i = ni;
            line = nl;
            continue;
        }
        // Raw strings r"..." / r#"..."# / br#"..."#.
        if (c == 'r' && i + 1 < n && (cs[i + 1] == '"' || cs[i + 1] == '#'))
            || (c == 'b' && i + 2 < n && cs[i + 1] == 'r' && (cs[i + 2] == '"' || cs[i + 2] == '#'))
        {
            let hash_start = if c == 'r' { i + 1 } else { i + 2 };
            let mut h = 0usize;
            let mut j = hash_start;
            while j < n && cs[j] == '#' {
                h += 1;
                j += 1;
            }
            if j < n && cs[j] == '"' {
                j += 1;
                let start = j;
                let tline = line;
                let mut end = n;
                while j < n {
                    if cs[j] == '\n' {
                        line += 1;
                        j += 1;
                        continue;
                    }
                    if cs[j] == '"' {
                        let mut m = 0usize;
                        while m < h && j + 1 + m < n && cs[j + 1 + m] == '#' {
                            m += 1;
                        }
                        if m == h {
                            end = j;
                            j += 1 + h;
                            break;
                        }
                    }
                    j += 1;
                }
                let content: String = cs[start..end].iter().collect();
                out.toks.push(Tok {
                    line: tline,
                    kind: TokKind::Str(content),
                });
                i = j;
                continue;
            }
            // Not a raw string after all (e.g. a raw identifier): fall
            // through to the ident path below.
        }
        // Normal string literal.
        if c == '"' {
            let tline = line;
            let (content, ni, nl) = lex_string(&cs, i, line);
            out.toks.push(Tok {
                line: tline,
                kind: TokKind::Str(content),
            });
            i = ni;
            line = nl;
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if i + 1 < n && (cs[i + 1].is_alphabetic() || cs[i + 1] == '_') {
                let mut j = i + 1;
                while j < n && (cs[j].is_alphanumeric() || cs[j] == '_') {
                    j += 1;
                }
                if j < n && cs[j] == '\'' {
                    i = j + 1; // char literal like 'a'
                } else {
                    i = j; // lifetime like 'static — ident not re-lexed
                }
                continue;
            }
            let mut j = i + 1;
            if j < n && cs[j] == '\\' {
                j += 2;
            } else {
                j += 1;
            }
            while j < n && cs[j] != '\'' {
                if cs[j] == '\n' {
                    line += 1;
                }
                j += 1;
            }
            i = (j + 1).min(n);
            continue;
        }
        // Numeric literal (int, float, hex, suffixed).
        if c.is_ascii_digit() {
            let start = i;
            let mut j = i;
            while j < n && (cs[j].is_ascii_alphanumeric() || cs[j] == '_') {
                j += 1;
            }
            if j < n && cs[j] == '.' && j + 1 < n && cs[j + 1].is_ascii_digit() {
                j += 1;
                while j < n && (cs[j].is_ascii_alphanumeric() || cs[j] == '_') {
                    j += 1;
                }
            }
            out.toks.push(Tok {
                line,
                kind: TokKind::Num(cs[start..j].iter().collect()),
            });
            i = j;
            continue;
        }
        // Identifier / keyword.
        if c.is_alphabetic() || c == '_' {
            let start = i;
            let mut j = i;
            while j < n && (cs[j].is_alphanumeric() || cs[j] == '_') {
                j += 1;
            }
            out.toks.push(Tok {
                line,
                kind: TokKind::Ident(cs[start..j].iter().collect()),
            });
            i = j;
            continue;
        }
        out.toks.push(Tok {
            line,
            kind: TokKind::Punct(c),
        });
        i += 1;
    }
    out
}

/// Consume a normal (escaped) string literal starting at the opening
/// quote; returns (unescaped content, next index, next line).
fn lex_string(cs: &[char], at: usize, mut line: usize) -> (String, usize, usize) {
    let n = cs.len();
    let mut j = at + 1;
    let mut content = String::new();
    while j < n {
        let c = cs[j];
        if c == '"' {
            j += 1;
            break;
        }
        if c == '\n' {
            line += 1;
            content.push('\n');
            j += 1;
            continue;
        }
        if c == '\\' && j + 1 < n {
            let e = cs[j + 1];
            match e {
                'n' => content.push('\n'),
                't' => content.push('\t'),
                'r' => content.push('\r'),
                '0' => content.push('\0'),
                '\\' => content.push('\\'),
                '\'' => content.push('\''),
                '"' => content.push('"'),
                'u' => {
                    // \u{...}: skip the payload, contribute nothing.
                    let mut k = j + 2;
                    if k < n && cs[k] == '{' {
                        while k < n && cs[k] != '}' {
                            k += 1;
                        }
                    }
                    j = (k + 1).min(n);
                    continue;
                }
                '\n' => line += 1, // line-continuation escape
                other => content.push(other),
            }
            j += 2;
            continue;
        }
        content.push(c);
        j += 1;
    }
    (content, j, line)
}

/// Recognize `lint:` pragmas in a line comment's text.
fn scan_pragma(comment: &str, line: usize, out: &mut Lexed) {
    // Doc comments arrive with a leading '/' or '!' still attached.
    let t = comment.trim_start_matches(['/', '!']).trim();
    if t == "lint: hot-path" || t == "lint:hot-path" {
        out.hot_lines.push(line);
        return;
    }
    if let Some(rest) = t.strip_prefix("lint:allow(") {
        if let Some(close) = rest.find(')') {
            let rule = rest[..close].trim().to_string();
            let justified = match rest[close + 1..].trim_start().strip_prefix(':') {
                Some(j) => !j.trim().is_empty(),
                None => false,
            };
            out.allows.push(Allow {
                line,
                rule,
                justified,
            });
        }
    }
}

/// Skip one item starting at `k`: leading `#[...]` attributes, then
/// either a `{ ... }` body (brace-matched) or a terminating `;`.
/// Returns the index just past the item.
fn skip_item(toks: &[Tok], mut k: usize) -> usize {
    while k + 1 < toks.len() && is_punct(&toks[k], '#') && is_punct(&toks[k + 1], '[') {
        let mut d = 0usize;
        while k < toks.len() {
            if is_punct(&toks[k], '[') {
                d += 1;
            } else if is_punct(&toks[k], ']') {
                d -= 1;
                if d == 0 {
                    k += 1;
                    break;
                }
            }
            k += 1;
        }
    }
    while k < toks.len() {
        if is_punct(&toks[k], ';') {
            return k + 1;
        }
        if is_punct(&toks[k], '{') {
            let mut d = 0usize;
            while k < toks.len() {
                if is_punct(&toks[k], '{') {
                    d += 1;
                } else if is_punct(&toks[k], '}') {
                    d -= 1;
                    if d == 0 {
                        return k + 1;
                    }
                }
                k += 1;
            }
            return k;
        }
        k += 1;
    }
    k
}

/// Drop tokens belonging to test-only regions: items annotated
/// `#[test]` / `#[cfg(test)]` (but *not* `#[cfg(not(test))]`), and
/// `mod tests { ... }` blocks.
pub fn strip_test_regions(toks: &[Tok]) -> Vec<Tok> {
    let mut out = Vec::with_capacity(toks.len());
    let mut i = 0usize;
    while i < toks.len() {
        if i + 1 < toks.len() && is_punct(&toks[i], '#') && is_punct(&toks[i + 1], '[') {
            let mut depth = 0usize;
            let mut j = i + 1;
            let mut idents: Vec<&str> = Vec::new();
            let mut close = None;
            while j < toks.len() {
                match &toks[j].kind {
                    TokKind::Punct('[') => depth += 1,
                    TokKind::Punct(']') => {
                        depth -= 1;
                        if depth == 0 {
                            close = Some(j);
                            break;
                        }
                    }
                    TokKind::Ident(s) => idents.push(s),
                    _ => {}
                }
                j += 1;
            }
            let Some(close) = close else {
                out.push(toks[i].clone());
                i += 1;
                continue;
            };
            let first = idents.first().copied().unwrap_or("");
            let is_test_attr = first == "test"
                || (first == "cfg"
                    && idents.iter().any(|s| *s == "test")
                    && !idents.iter().any(|s| *s == "not"));
            if is_test_attr {
                i = skip_item(toks, close + 1);
            } else {
                out.extend(toks[i..=close].iter().cloned());
                i = close + 1;
            }
            continue;
        }
        if ident_is(&toks[i], "mod") && ident_at(toks, i + 1, "tests") {
            i = skip_item(toks, i + 2);
            continue;
        }
        out.push(toks[i].clone());
        i += 1;
    }
    out
}

/// Find function items and their brace-matched body spans in a
/// (test-stripped) token stream. A `// lint: hot-path` annotation
/// attaches to the next `fn` at a later (or equal) line.
pub fn fn_spans(toks: &[Tok], hot_lines: &[usize]) -> Vec<FnSpan> {
    let mut spans = Vec::new();
    let mut hots: Vec<usize> = hot_lines.to_vec();
    hots.sort_unstable();
    let mut next_hot = 0usize;
    let mut i = 0usize;
    while i < toks.len() {
        if ident_is(&toks[i], "fn") && i + 1 < toks.len() {
            if let TokKind::Ident(name) = &toks[i + 1].kind {
                let fn_line = toks[i].line;
                let mut hot = false;
                while next_hot < hots.len() && hots[next_hot] <= fn_line {
                    hot = true;
                    next_hot += 1;
                }
                let mut k = i + 2;
                let mut body = None;
                while k < toks.len() {
                    if is_punct(&toks[k], ';') {
                        break; // trait method without a body
                    }
                    if is_punct(&toks[k], '{') {
                        let start = k;
                        let mut d = 0usize;
                        while k < toks.len() {
                            if is_punct(&toks[k], '{') {
                                d += 1;
                            } else if is_punct(&toks[k], '}') {
                                d -= 1;
                                if d == 0 {
                                    break;
                                }
                            }
                            k += 1;
                        }
                        body = Some((start, k.min(toks.len() - 1)));
                        break;
                    }
                    k += 1;
                }
                if let Some(body) = body {
                    spans.push(FnSpan {
                        name: name.clone(),
                        line: fn_line,
                        body,
                        hot,
                    });
                }
                i += 2;
                continue;
            }
        }
        i += 1;
    }
    spans
}

fn allowed(allows: &[Allow], rule: &str, line: usize, need_justification: bool) -> bool {
    allows.iter().any(|a| {
        a.rule == rule
            && (a.line == line || a.line + 1 == line)
            && (!need_justification || a.justified)
    })
}

/// Is any path component exactly `module` (e.g. `obs` in
/// `rust/src/obs/clock.rs`)?
fn in_module(path: &str, module: &str) -> bool {
    path.split(['/', '\\']).any(|c| c == module)
}

/// Extract `"key":`-shaped JSON keys from an (unescaped) string
/// literal's content. Only identifier-like keys are reported, so format
/// placeholders (`{}`) and interpolated values never false-positive.
pub fn extract_json_keys(content: &str) -> Vec<String> {
    let cs: Vec<char> = content.chars().collect();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < cs.len() {
        if cs[i] != '"' {
            i += 1;
            continue;
        }
        let start = i + 1;
        let mut j = start;
        while j < cs.len() && cs[j] != '"' {
            j += 1;
        }
        if j >= cs.len() {
            break;
        }
        let cand: String = cs[start..j].iter().collect();
        let mut k = j + 1;
        while k < cs.len() && cs[k].is_whitespace() {
            k += 1;
        }
        if k < cs.len() && cs[k] == ':' && is_ident_like(&cand) {
            out.push(cand);
        }
        i = j + 1;
    }
    out
}

fn is_ident_like(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// Run all rules over one file's source. `file` should be the
/// workspace-relative path — module exemptions (`obs/`, `quant/`,
/// `fp8/`) match on its components.
pub fn check_file(file: &str, src: &str, schema: Option<&Schema>) -> Vec<Diag> {
    let lexed = lex(src);
    let toks = strip_test_regions(&lexed.toks);
    let spans = fn_spans(&toks, &lexed.hot_lines);
    let allows = &lexed.allows;
    let mut diags: Vec<Diag> = Vec::new();
    let mut push = |diags: &mut Vec<Diag>, line: usize, rule: &'static str, message: String| {
        diags.push(Diag {
            file: file.to_string(),
            line,
            rule,
            message,
        });
    };

    // clock-discipline
    if !in_module(file, "obs") {
        for t in &toks {
            if let TokKind::Ident(s) = &t.kind {
                if (s == "Instant" || s == "SystemTime")
                    && !allowed(allows, RULE_CLOCK, t.line, false)
                {
                    push(
                        &mut diags,
                        t.line,
                        RULE_CLOCK,
                        format!("`{s}` outside obs/ — route timing through obs::Clock"),
                    );
                }
            }
        }
    }

    // bytes-through-layout
    if !in_module(file, "quant") && !in_module(file, "fp8") {
        for t in &toks {
            if ident_is(t, "size_of") && !allowed(allows, RULE_BYTES, t.line, false) {
                push(
                    &mut diags,
                    t.line,
                    RULE_BYTES,
                    "`size_of` outside quant//fp8/ — derive byte rates from quant::KvLayout"
                        .to_string(),
                );
            }
        }
        for sp in &spans {
            // Swap/transfer paths (the ISSUE 9 host KV tier) move the same
            // accounted bytes across the PCIe link, so their functions are
            // held to the layout discipline even without "byte" in the name.
            if !(sp.name.contains("byte")
                || sp.name.contains("swap")
                || sp.name.contains("transfer"))
            {
                continue;
            }
            let (b0, b1) = sp.body;
            for j in b0..b1.saturating_sub(1) {
                if let (TokKind::Num(a), TokKind::Punct('*'), TokKind::Num(b)) =
                    (&toks[j].kind, &toks[j + 1].kind, &toks[j + 2].kind)
                {
                    if !allowed(allows, RULE_BYTES, toks[j].line, false) {
                        push(
                            &mut diags,
                            toks[j].line,
                            RULE_BYTES,
                            format!(
                                "raw byte multiplication `{a} * {b}` in `{}` — \
                                 name the widths via quant::KvLayout-derived constants",
                                sp.name
                            ),
                        );
                    }
                }
            }
        }
    }

    // hot-path-no-alloc
    for sp in &spans {
        if !sp.hot {
            continue;
        }
        let (b0, b1) = sp.body;
        for j in b0..=b1 {
            let what = if ident_is(&toks[j], "Vec")
                && punct_at(&toks, j + 1, ':')
                && punct_at(&toks, j + 2, ':')
                && ident_at(&toks, j + 3, "new")
            {
                Some("Vec::new")
            } else if ident_is(&toks[j], "vec") && punct_at(&toks, j + 1, '!') {
                Some("vec!")
            } else if is_punct(&toks[j], '.') && ident_at(&toks, j + 1, "to_vec") {
                Some(".to_vec()")
            } else if is_punct(&toks[j], '.')
                && ident_at(&toks, j + 1, "clone")
                && punct_at(&toks, j + 2, '(')
            {
                Some(".clone()")
            } else if is_punct(&toks[j], '.') && ident_at(&toks, j + 1, "collect") {
                Some(".collect()")
            } else {
                None
            };
            if let Some(what) = what {
                if !allowed(allows, RULE_HOT, toks[j].line, false) {
                    push(
                        &mut diags,
                        toks[j].line,
                        RULE_HOT,
                        format!(
                            "`{what}` inside hot-path fn `{}` — the paged decode \
                             path must stay allocation-free",
                            sp.name
                        ),
                    );
                }
            }
        }
    }

    // no-unwrap-in-lib
    for j in 0..toks.len() {
        let (what, line) = if is_punct(&toks[j], '.')
            && ident_at(&toks, j + 1, "unwrap")
            && punct_at(&toks, j + 2, '(')
            && punct_at(&toks, j + 3, ')')
        {
            (Some(".unwrap()"), toks[j + 1].line)
        } else if is_punct(&toks[j], '.')
            && ident_at(&toks, j + 1, "expect")
            && punct_at(&toks, j + 2, '(')
        {
            (Some(".expect("), toks[j + 1].line)
        } else if ident_is(&toks[j], "panic") && punct_at(&toks, j + 1, '!') {
            (Some("panic!"), toks[j].line)
        } else {
            (None, 0)
        };
        if let Some(what) = what {
            if !allowed(allows, RULE_UNWRAP, line, true) {
                push(
                    &mut diags,
                    line,
                    RULE_UNWRAP,
                    format!(
                        "`{what}` in non-test library code — convert to a typed \
                         error or justify with `// lint:allow(no-unwrap-in-lib): <why>`"
                    ),
                );
            }
        }
    }

    // bench-json-schema
    if let Some(schema) = schema {
        for sp in &spans {
            if !sp.name.contains("json_row") {
                continue;
            }
            let (b0, b1) = sp.body;
            for j in b0..=b1 {
                if let TokKind::Str(content) = &toks[j].kind {
                    for key in extract_json_keys(content) {
                        if !schema.contains(&key) && !allowed(allows, RULE_JSON, toks[j].line, false)
                        {
                            push(
                                &mut diags,
                                toks[j].line,
                                RULE_JSON,
                                format!(
                                    "json key \"{key}\" emitted by `{}` is not declared \
                                     in the bench schema list",
                                    sp.name
                                ),
                            );
                        }
                    }
                }
            }
        }
    }

    diags
}

/// Recursively collect `.rs` files under each path (files pass through).
pub fn collect_rs_files(paths: &[PathBuf]) -> io::Result<Vec<PathBuf>> {
    fn walk(p: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
        if fs::metadata(p)?.is_dir() {
            for entry in fs::read_dir(p)? {
                walk(&entry?.path(), out)?;
            }
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p.to_path_buf());
        }
        Ok(())
    }
    let mut out = Vec::new();
    for p in paths {
        walk(p, &mut out)?;
    }
    out.sort();
    Ok(out)
}

/// Lint every `.rs` file under `paths`; diagnostics come back sorted by
/// (file, line, rule) so output and golden files are deterministic.
pub fn lint_paths(paths: &[PathBuf], schema: Option<&Schema>) -> io::Result<Vec<Diag>> {
    let files = collect_rs_files(paths)?;
    let mut diags = Vec::new();
    for f in &files {
        let src = fs::read_to_string(f)?;
        let rel = f.to_string_lossy().replace('\\', "/");
        diags.extend(check_file(&rel, &src, schema));
    }
    diags.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(diags)
}

pub fn render_human(d: &Diag) -> String {
    format!("{}:{}: [{}] {}", d.file, d.line, d.rule, d.message)
}

/// Serialize diagnostics as a JSON array (hand-rolled: the crate is
/// dependency-free by design).
pub fn diags_to_json(diags: &[Diag]) -> String {
    let mut s = String::from("[");
    for (i, d) in diags.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str("\n  {");
        s.push_str(&format!("\"file\":{},", json_str(&d.file)));
        s.push_str(&format!("\"line\":{},", d.line));
        s.push_str(&format!("\"rule\":{},", json_str(d.rule)));
        s.push_str(&format!("\"message\":{}", json_str(&d.message)));
        s.push('}');
    }
    if !diags.is_empty() {
        s.push('\n');
    }
    s.push_str("]\n");
    s
}

pub fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter_map(|t| match t.kind {
                TokKind::Ident(s) => Some(s),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn strings_and_comments_are_not_code() {
        let src = r#"
            // Instant in a comment
            /* Instant in /* a nested */ block comment */
            fn f() -> &'static str { "Instant::now()" }
        "#;
        let ids = idents(src);
        assert!(!ids.contains(&"Instant".to_string()), "{ids:?}");
        assert!(ids.contains(&"f".to_string()));
        // The string content survives as a Str token.
        let lexed = lex(src);
        assert!(lexed
            .toks
            .iter()
            .any(|t| matches!(&t.kind, TokKind::Str(s) if s == "Instant::now()")));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let src = "fn g<'a>(x: &'a str) -> char { let c = 'x'; let q = '\\''; c }";
        let ids = idents(src);
        assert!(ids.contains(&"g".to_string()));
        // Lifetime name is skipped, not lexed as an ident; the parameter
        // names still are.
        assert!(!ids.contains(&"a".to_string()), "{ids:?}");
        assert!(ids.contains(&"c".to_string()));
    }

    #[test]
    fn raw_strings_are_skipped_whole() {
        let src = r##"fn h() { let s = r#"Instant "quoted" inside"#; }"##;
        let ids = idents(src);
        assert!(!ids.contains(&"Instant".to_string()), "{ids:?}");
    }

    #[test]
    fn cfg_test_and_mod_tests_are_stripped() {
        let src = r#"
            fn live() { x.unwrap(); }
            #[cfg(test)]
            mod tests {
                fn t() { y.unwrap(); panic!("boom"); }
            }
            #[test]
            fn unit() { z.unwrap(); }
        "#;
        let lexed = lex(src);
        let toks = strip_test_regions(&lexed.toks);
        let unwraps = toks.iter().filter(|t| ident_is(t, "unwrap")).count();
        assert_eq!(unwraps, 1, "only the live fn's unwrap survives");
        assert!(!toks.iter().any(|t| ident_is(t, "panic")));
    }

    #[test]
    fn cfg_not_test_is_kept() {
        let src = "#[cfg(not(test))] fn real() { a.unwrap(); }";
        let lexed = lex(src);
        let toks = strip_test_regions(&lexed.toks);
        assert!(toks.iter().any(|t| ident_is(t, "unwrap")));
    }

    #[test]
    fn pragma_parsing() {
        let src = "
            // lint:allow(no-unwrap-in-lib): queue checked non-empty above
            x.unwrap();
            y.expect(\"msg\"); // lint:allow(no-unwrap-in-lib)
            // lint: hot-path
            fn hot() {}
        ";
        let lexed = lex(src);
        assert_eq!(lexed.allows.len(), 2);
        assert!(lexed.allows[0].justified);
        assert_eq!(lexed.allows[0].rule, "no-unwrap-in-lib");
        assert!(!lexed.allows[1].justified);
        assert_eq!(lexed.hot_lines.len(), 1);
        let toks = strip_test_regions(&lexed.toks);
        let spans = fn_spans(&toks, &lexed.hot_lines);
        assert_eq!(spans.len(), 1);
        assert!(spans[0].hot);
        assert_eq!(spans[0].name, "hot");
    }

    #[test]
    fn unwrap_rule_requires_justification() {
        let src = "
            fn f() {
                a.unwrap(); // lint:allow(no-unwrap-in-lib)
            }
        ";
        let diags = check_file("rust/src/x.rs", src, None);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].rule, RULE_UNWRAP);
        let src_ok = "
            fn f() {
                a.unwrap(); // lint:allow(no-unwrap-in-lib): invariant: a is Some here
            }
        ";
        assert!(check_file("rust/src/x.rs", src_ok, None).is_empty());
    }

    #[test]
    fn json_keys_extraction() {
        let keys = extract_json_keys("{\"label\":\"{}\",\"ttft_mean_ms\":{:.3},");
        assert_eq!(keys, vec!["label".to_string(), "ttft_mean_ms".to_string()]);
        // Placeholders and values are not keys.
        assert!(extract_json_keys("\"{}\" , \"serve\",").is_empty());
    }

    #[test]
    fn bytes_rule_covers_swap_and_transfer_named_fns() {
        // Raw literal byte math inside swap/transfer paths is held to the
        // same KvLayout discipline as *byte*-named functions (ISSUE 9:
        // the host tier moves accounted bytes across the PCIe link).
        for name in ["swap_out_cost", "host_transfer_budget", "kv_bytes_for"] {
            let src = format!("fn {name}() -> usize {{ 4 * 16 }}");
            let diags = check_file("rust/src/x.rs", &src, None);
            assert_eq!(diags.len(), 1, "{name}: {diags:?}");
            assert_eq!(diags[0].rule, RULE_BYTES);
        }
        // Functions outside the naming net keep their literal math...
        let free = "fn unrelated_math() -> usize { 4 * 16 }";
        assert!(check_file("rust/src/x.rs", free, None).is_empty());
        // ...and quant/ owns the byte-rate definitions, so it is exempt.
        let quant = "fn swap_block_bytes() -> usize { 4 * 16 }";
        assert!(check_file("rust/src/quant/x.rs", quant, None).is_empty());
    }

    #[test]
    fn json_output_escapes() {
        let d = Diag {
            file: "a\"b.rs".to_string(),
            line: 3,
            rule: RULE_CLOCK,
            message: "tab\there".to_string(),
        };
        let j = diags_to_json(&[d]);
        assert!(j.contains("a\\\"b.rs"));
        assert!(j.contains("tab\\there"));
    }
}
