//! Golden-diagnostics tests over the fixture corpus, plus a live check
//! that the real `rust/src` tree is lint-clean (the same gate CI's
//! `lint` job enforces with `--deny`).

use std::fs;
use std::path::{Path, PathBuf};

use repro_lint::{check_file, collect_rs_files, diags_to_json, lint_paths, Diag, Schema};

fn manifest_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// Lint every fixture with a stable `fixtures/<name>` label so the
/// golden JSON is independent of where the checkout lives.
fn lint_fixtures() -> Vec<Diag> {
    let dir = manifest_dir().join("fixtures");
    let schema = Schema::load(&dir.join("schema.txt")).expect("fixture schema");
    let files = collect_rs_files(&[dir]).expect("fixture dir");
    assert!(files.len() >= 7, "fixture corpus went missing: {files:?}");
    let mut diags = Vec::new();
    for f in &files {
        let src = fs::read_to_string(f).expect("fixture source");
        let name = format!(
            "fixtures/{}",
            f.file_name().expect("file name").to_string_lossy()
        );
        diags.extend(check_file(&name, &src, Some(&schema)));
    }
    diags.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    diags
}

#[test]
fn fixture_diagnostics_match_golden_json() {
    let got = diags_to_json(&lint_fixtures());
    let golden = manifest_dir().join("fixtures").join("expected.json");
    let want = fs::read_to_string(&golden).expect("golden json");
    assert_eq!(
        got, want,
        "fixture diagnostics drifted from fixtures/expected.json — \
         regenerate the golden only for intentional rule changes"
    );
}

#[test]
fn per_fixture_expectations() {
    let diags = lint_fixtures();
    let count = |file: &str| diags.iter().filter(|d| d.file.ends_with(file)).count();
    // Known-bad snippets fire; pragma'd and test-only code stays silent.
    assert_eq!(count("bad_clock.rs"), 2);
    assert_eq!(count("bad_bytes.rs"), 2);
    assert_eq!(count("bad_hotpath.rs"), 5, "warm()'s pragma must be honored");
    assert_eq!(count("bad_unwrap.rs"), 2, "unjustified pragma must not count");
    assert_eq!(count("bad_json_row.rs"), 1);
    assert_eq!(count("good_testcode.rs"), 0, "#[cfg(test)]/#[test] excluded");
    assert_eq!(count("good_strings.rs"), 0, "strings/comments are immune");
}

#[test]
fn repo_tree_is_clean() {
    let root = manifest_dir()
        .parent()
        .and_then(Path::parent)
        .map(Path::to_path_buf)
        .expect("workspace root");
    let src = root.join("rust").join("src");
    if !src.is_dir() {
        return; // sliced checkout without the main crate
    }
    let schema = Schema::load(&manifest_dir().join("bench_schema.txt")).expect("bench schema");
    let diags = lint_paths(&[src], Some(&schema)).expect("lint rust/src");
    assert!(
        diags.is_empty(),
        "rust/src must stay lint-clean:\n{}",
        diags
            .iter()
            .map(repro_lint::render_human)
            .collect::<Vec<_>>()
            .join("\n")
    );
}
