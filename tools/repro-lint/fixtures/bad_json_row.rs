pub fn emit_json_row(v: u64) -> String {
    format!("{{\"label\":\"fixture\",\"bogus_key\":{}}}", v)
}

pub fn other_emitter(v: u64) -> String {
    format!("{{\"unchecked_key\":{}}}", v)
}
