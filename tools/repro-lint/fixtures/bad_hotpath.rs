// lint: hot-path
pub fn read_tile(src: &[f32]) -> Vec<f32> {
    let a: Vec<f32> = Vec::new();
    let b = vec![0.0f32; 4];
    let c = src.to_vec();
    let d = c.clone();
    let e: Vec<f32> = src.iter().copied().collect();
    [a, b, c, d, e].concat()
}

// lint: hot-path
pub fn warm(src: &[f32]) -> Vec<f32> {
    src.to_vec() // lint:allow(hot-path-no-alloc): one-time warmup scratch, not per-step
}

pub fn cold(src: &[f32]) -> Vec<f32> {
    src.to_vec()
}
