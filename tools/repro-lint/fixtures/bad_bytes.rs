pub fn tile_bytes_per_head() -> usize {
    2 * 4
}

pub fn payload_elems() -> usize {
    std::mem::size_of::<f32>()
}
