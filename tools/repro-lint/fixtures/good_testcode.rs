pub fn lib_fn(x: Option<u32>) -> u32 {
    x.unwrap_or(0)
}

#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        let t0 = std::time::Instant::now();
        Some(1u32).unwrap();
        assert!(t0.elapsed().as_secs_f64() >= 0.0);
        panic!("fine in tests");
    }
}

#[test]
fn free_test() {
    None::<u32>.unwrap();
}
