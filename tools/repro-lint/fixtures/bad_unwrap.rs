pub fn first(xs: &[u32]) -> u32 {
    *xs.first().unwrap()
}

pub fn second(xs: &[u32]) -> u32 {
    *xs.get(1).expect("len checked") // lint:allow(no-unwrap-in-lib)
}

pub fn third() -> u32 {
    panic!("boom") // lint:allow(no-unwrap-in-lib): fixtures demonstrate a justified pragma
}
