//! Instant, SystemTime, size_of, and panic appear here only in prose.

/* block comment: Instant::now() and a vec![] of SystemTime */
pub fn describe() -> &'static str {
    "Instant::now() .unwrap() panic! size_of 2 * 4"
}

pub fn raw() -> &'static str {
    r#"SystemTime "quoted" .expect("x")"#
}

pub fn anchored_ms() -> u64 {
    // lint:allow(clock-discipline): fixture shows the line-above pragma form
    let t = std::time::Instant::now();
    t.elapsed().as_millis() as u64
}
