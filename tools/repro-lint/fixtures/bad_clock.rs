use std::time::Instant;

pub fn measure() -> f64 {
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}
